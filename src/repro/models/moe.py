"""Mixture-of-Experts with expert-parallel (EP) dispatch.

Production path (``_ep_moe``): runs inside ``shard_map`` with experts sharded
over the model axis. Dispatch is sort-based with static capacity:

  router top-k -> counts -> *exclusive prefix scan* for per-expert offsets
  (the paper's primitive, via the Pallas prefix-scan kernel path) ->
  scatter into (E, C, d) -> all_to_all -> expert FFN -> all_to_all back ->
  weighted combine.

Fallback path (``_dense_moe``): dropless einsum over all experts — used on
single-device smoke meshes and when E doesn't divide the model axis.

Aux losses (load-balance + router z-loss) are psum-averaged across the mesh.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.kernels.ops import prefix_scan
from repro.models.layers import _ACT
from repro.sharding import current_topology

Params = Dict[str, Any]


def init_moe(key, cfg, dtype) -> Params:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.moe_num_experts
    ks = jax.random.split(key, 5)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff)
    p = {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * s_in,
        "w_in": jax.random.normal(ks[1], (E, d, ff), dtype) * s_in,
        "w_gate": jax.random.normal(ks[2], (E, d, ff), dtype) * s_in,
        "w_out": jax.random.normal(ks[3], (E, ff, d), dtype) * s_out,
    }
    if cfg.moe_num_shared:
        sh_ff = cfg.moe_num_shared * ff
        km = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_in": jax.random.normal(km[0], (d, sh_ff), dtype) * s_in,
            "w_gate": jax.random.normal(km[1], (d, sh_ff), dtype) * s_in,
            "w_out": jax.random.normal(km[2], (sh_ff, d), dtype) * s_out,
        }
    if not cfg.gated_mlp:
        p.pop("w_gate")
        if "shared" in p:
            p["shared"].pop("w_gate")
    return p


def _router(logits: jax.Array, k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (gates (n,k), experts (n,k), probs (n,E))."""
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = lax.top_k(probs, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, experts, probs


def _aux_losses(probs: jax.Array, experts: jax.Array, E: int,
                logits=None) -> Tuple[jax.Array, jax.Array]:
    """Switch-style load-balance loss + router z-loss (local means)."""
    n, k = experts.shape
    onehot = jax.nn.one_hot(experts, E, dtype=jnp.float32)  # (n,k,E)
    frac_tokens = onehot.sum((0, 1)) / (n * k)
    frac_probs = probs.mean(0)
    lb = E * jnp.sum(frac_tokens * frac_probs)
    zin = logits if logits is not None else jnp.log(probs + 1e-20)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(zin, axis=-1)))
    return lb, z


def _expert_ffn(p: Params, x: jax.Array, act: str) -> jax.Array:
    """x: (E_loc, C', d) -> (E_loc, C', d)."""
    a = _ACT[act]
    h = jnp.einsum("ecd,edf->ecf", x, p["w_in"])
    if "w_gate" in p:
        h = a(jnp.einsum("ecd,edf->ecf", x, p["w_gate"])) * h
    else:
        h = a(h)
    return jnp.einsum("ecf,efd->ecd", h, p["w_out"])


def _shared_ffn(p: Params, x: jax.Array, act: str) -> jax.Array:
    a = _ACT[act]
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"])
    if "w_gate" in p:
        h = a(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * h
    else:
        h = a(h)
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"])


def _dense_moe(p: Params, x: jax.Array, cfg, act: str):
    """Dropless reference path: every expert sees every token (masked)."""
    B, S, d = x.shape
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    xf = x.reshape(-1, d)
    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    gates, experts, probs = _router(logits, k)
    lb, z = _aux_losses(probs, experts, E, logits)
    # combine weights (n, E)
    comb = jnp.zeros((xf.shape[0], E), x.dtype)
    comb = comb.at[jnp.arange(xf.shape[0])[:, None], experts].add(
        gates.astype(x.dtype)
    )
    h = jnp.einsum("nd,edf->nef", xf, p["w_in"])
    if "w_gate" in p:
        h = _ACT[act](jnp.einsum("nd,edf->nef", xf, p["w_gate"])) * h
    else:
        h = _ACT[act](h)
    y = jnp.einsum("nef,efd->ned", h, p["w_out"])
    out = jnp.einsum("ned,ne->nd", y, comb).reshape(B, S, d)
    if "shared" in p:
        out = out + _shared_ffn(p["shared"], x, act)
    return out, {"load_balance": lb, "router_z": z}


def _ep_region(x, router, w_in, w_gate, w_out, *, cfg, act, axis, ep, dp_axes):
    """Per-device EP dispatch. x: (B_loc, S_loc, d); experts sharded E_loc."""
    B, S, d = x.shape
    n = B * S
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    C = int(math.ceil(n * k / E * cfg.capacity_factor))
    # round capacity to a lane multiple so the (E, C, d) buffer tiles cleanly
    C = max(8, -(-C // 8) * 8)

    xf = x.reshape(n, d)
    logits = (xf.astype(jnp.float32) @ router).astype(jnp.float32)
    gates, experts, probs = _router(logits, k)
    # globally-exact aux stats: pmean the sufficient statistics FIRST
    axes = tuple(dp_axes) + (axis,)
    onehot = jax.nn.one_hot(experts, E, dtype=jnp.float32)
    frac_tokens = lax.pmean(onehot.sum((0, 1)) / (n * k), axes)
    frac_probs = lax.pmean(probs.mean(0), axes)
    lb = E * jnp.sum(frac_tokens * frac_probs)
    z = lax.pmean(
        jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))), axes
    )

    flat_e = experts.reshape(-1)                      # (nk,)
    flat_g = gates.reshape(-1).astype(x.dtype)
    nk = n * k
    counts = jnp.sum(jax.nn.one_hot(flat_e, E, dtype=jnp.int32), axis=0)  # (E,)
    # per-expert offsets: THE PAPER'S PRIMITIVE — exclusive prefix scan
    starts = prefix_scan(counts[None, :], op="add", exclusive=True)[0]
    order = jnp.argsort(flat_e)
    pos_sorted = jnp.arange(nk, dtype=jnp.int32) - starts[flat_e[order]]
    pos = jnp.zeros((nk,), jnp.int32).at[order].set(pos_sorted)

    tok = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
    slot = jnp.where(pos < C, flat_e * C + pos, E * C)  # OOB -> dropped
    buf = jnp.zeros((E * C, d), x.dtype).at[slot].set(
        xf[tok], mode="drop"
    )
    # all_to_all: expert-group i goes to device i; my experts' tokens arrive
    # concatenated along capacity: (E, C, d) -> (E_loc, ep*C, d)
    buf = buf.reshape(E, C, d)
    buf = lax.all_to_all(buf, axis, split_axis=0, concat_axis=1, tiled=True)

    ep_params = {"w_in": w_in, "w_out": w_out}
    if w_gate is not None:
        ep_params["w_gate"] = w_gate
    out = _expert_ffn(ep_params, buf, act)

    # reverse: (E_loc, ep*C, d) -> (E, C, d)
    out = lax.all_to_all(out, axis, split_axis=1, concat_axis=0, tiled=True)
    out = out.reshape(E * C, d)
    got = out.at[slot].get(mode="fill", fill_value=0)  # (nk, d)
    y = jnp.zeros((n, d), x.dtype).at[tok].add(got * flat_g[:, None])
    return y.reshape(B, S, d), lb, z


def moe_block(p: Params, x: jax.Array, cfg, *, act: str = "silu"):
    """Top-level MoE FFN. Chooses EP (shard_map) or dense fallback."""
    topo = current_topology()
    E = cfg.moe_num_experts
    ep = topo.model_size
    if topo.mesh is None or ep == 1 or E % ep != 0:
        return _dense_moe(p, x, cfg, act)

    axis = topo.model_axis
    dp = topo.batch_axes
    B, S, d = x.shape
    gated = "w_gate" in p
    dpspec = dp[0] if len(dp) == 1 else dp

    # tokens: batch over dp; sequence over the model axis (SP) when it
    # divides, else fold the model axis into batch (decode), else replicate.
    dp_size = topo.dp_size
    if S % ep == 0 and B % dp_size == 0:
        x_spec = P(dpspec, axis, None)
    elif B % (dp_size * ep) == 0:
        x_spec = P(tuple(dp) + (axis,), None, None)
    elif B % dp_size == 0:
        x_spec = P(dpspec, None, None)
    else:
        x_spec = P(None, None, None)

    def region(x_l, router, w_in, w_gate, w_out):
        return _ep_region(
            x_l, router, w_in, w_gate, w_out,
            cfg=cfg, act=act, axis=axis, ep=ep, dp_axes=dp,
        )

    def region_plain(x_l, router, w_in, w_out):
        return _ep_region(
            x_l, router, w_in, None, w_out,
            cfg=cfg, act=act, axis=axis, ep=ep, dp_axes=dp,
        )

    w_spec = P(axis, None, None)
    if gated:
        mapped = shard_map(
            region,
            mesh=topo.mesh,
            in_specs=(x_spec, P(None, None), w_spec, w_spec, w_spec),
            out_specs=(x_spec, P(), P()),
            check_vma=False,
        )
        y, lb, z = mapped(x, p["router"], p["w_in"], p["w_gate"], p["w_out"])
    else:
        mapped = shard_map(
            region_plain,
            mesh=topo.mesh,
            in_specs=(x_spec, P(None, None), w_spec, w_spec),
            out_specs=(x_spec, P(), P()),
            check_vma=False,
        )
        y, lb, z = mapped(x, p["router"], p["w_in"], p["w_out"])
    if "shared" in p:
        y = y + _shared_ffn(p["shared"], x, act)
    return y, {"load_balance": lb, "router_z": z}
