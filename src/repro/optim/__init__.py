from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state, lr_at
from repro.optim.compression import (
    compress_with_feedback,
    compressed_allreduce_mean,
    dequantize_int8,
    quantize_int8,
)
