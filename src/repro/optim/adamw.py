"""AdamW with f32 master weights, global-norm clipping, cosine schedule.

ZeRO-1 falls out of sharding specs, not code: optimizer state (m, v, master)
carries an extra 'data'-axis sharding on top of the parameter's TP spec
(see sharding/rules.py), so XLA reduce-scatters gradients into the update and
all-gathers the bf16 working params afterwards — the standard GSPMD
realization of sharded optimizer state.
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

Params = Any


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(step: jax.Array, c: AdamWConfig) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = c.lr * step / max(c.warmup_steps, 1)
    prog = jnp.clip(
        (step - c.warmup_steps) / max(c.total_steps - c.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = c.lr * (
        c.min_lr_ratio + (1 - c.min_lr_ratio) * 0.5 * (1 + jnp.cos(math.pi * prog))
    )
    return jnp.where(step < c.warmup_steps, warm, cos)


def init_opt_state(params: Params) -> Dict[str, Any]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        # copy=True: master must never alias the bf16/f32 working params
        # (both are donated by train_step; aliased buffers break donation)
        "master": jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params
        ),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(
        sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(tree)
        )
    )


def adamw_update(
    grads: Params,
    opt_state: Dict[str, Any],
    params: Params,
    cfg: AdamWConfig,
) -> tuple[Params, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step. Returns (new_params_bf16, new_opt_state, stats)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(count, cfg)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * master
        master = master - lr * step
        return m, v, master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_ma = treedef.flatten_up_to(opt_state["master"])
    out = [upd(g, m, v, ma) for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_master = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(
        lambda ma, p: ma.astype(p.dtype), new_master, params
    )
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, {
        "m": new_m,
        "v": new_v,
        "master": new_master,
        "count": count,
    }, stats
