"""Gradient compression: int8 quantization with error feedback.

At 1000+ nodes the cross-pod (DCN) links are the scarce resource; 4x smaller
gradient payloads with error-feedback accumulation is the standard remedy
(1-bit Adam / PowerSGD lineage — we implement the int8+EF point, which
composes with any optimizer because the compression error is re-injected
into the next step's gradient rather than lost).

``compressed_allreduce_mean`` is the shard_map building block: quantize ->
psum -> dequantize, with the quantization residual returned for feedback.
examples/compressed_dp.py demonstrates convergence parity on 8 devices.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_with_feedback(
    grads: PyTree, error: Optional[PyTree]
) -> Tuple[PyTree, PyTree, PyTree]:
    """Quantize (grads + error); new error = input - dequantized.

    Returns (q_tree, scale_tree, new_error_tree).
    """
    if error is None:
        error = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = quantize_int8(x)
        deq = dequantize_int8(q, s)
        return q, s, x - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    unf = lambda i: jax.tree_util.tree_unflatten(treedef, [o[i] for o in outs])
    return unf(0), unf(1), unf(2)


def compressed_allreduce_mean(
    grads: PyTree, axis_name: str, error: Optional[PyTree] = None
) -> Tuple[PyTree, PyTree]:
    """DP gradient mean with int8 payloads + error feedback (in shard_map).

    int8 doesn't survive summation (overflow), so the wire format is int8 but
    the psum runs on the dequantized f32 of WIDTH int8 payload semantics:
    each rank contributes its quantized value; the quantization error stays
    local in the feedback buffer. Wire bytes: 1/4 of f32.
    """
    q, s, new_err = compress_with_feedback(grads, error)
    p = lax.psum(1, axis_name)

    def reduce_one(qi, si, g):
        # transmit int8 + scalar scale; average of dequantized values
        deq = dequantize_int8(qi, si)
        tot = lax.psum(deq, axis_name)
        return (tot / p).astype(g.dtype)

    mean = jax.tree.map(reduce_one, q, s, grads)
    return mean, new_err
