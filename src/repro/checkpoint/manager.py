"""Checkpoint manager: atomic, keep-k, background writes, crash-safe restore.

Layout:  <dir>/step_<n>/  arrays.npz + tree.json   (+ .tmp staging)
A checkpoint becomes visible only via the final atomic rename, so a process
killed mid-write never corrupts the restore path — the fault-tolerance story
(runtime/) leans on this.
"""

from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: PyTree, *, block: bool = False) -> None:
        # materialize on host BEFORE handing to the writer thread, so the
        # caller may donate/overwrite device buffers immediately
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]
        treedef_str = str(treedef)

        def write():
            tmp = self.dir / f".tmp_step_{step}"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "arrays.npz", **{
                f"leaf_{i}": a for i, a in enumerate(host_leaves)
            })
            (tmp / "tree.json").write_text(json.dumps({
                "step": step,
                "n_leaves": len(host_leaves),
                "treedef": treedef_str,
            }))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)  # atomic visibility
            self._gc()

        if self.async_write and not block:
            self.wait()
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            try:
                out.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, like: PyTree, step: Optional[int] = None,
        shardings: Optional[PyTree] = None,
    ) -> Tuple[int, PyTree]:
        """Restore into the structure of ``like``; returns (step, tree).

        With ``shardings`` given, leaves are device_put against them (the
        resume path re-lays-out a checkpoint onto a possibly DIFFERENT mesh —
        elastic re-mesh restores go through exactly this call).
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step}"
        data = np.load(d / "arrays.npz")
        leaves, treedef = _flatten(like)
        assert len(leaves) == len(data.files), (len(leaves), len(data.files))
        new_leaves = []
        for i, ref in enumerate(leaves):
            arr = data[f"leaf_{i}"]
            arr = arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr
            new_leaves.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s) if s is not None else jax.device_put(a),
                tree, shardings,
                is_leaf=lambda x: isinstance(x, np.ndarray),
            )
        return step, tree
