"""Batched serving engine: continuous-batching decode over prefilled caches.

One fixed-capacity decode batch; requests occupy slots. prefill() computes a
prompt's cache (via the model's collect-cache forward) and splices it into
the slot's rows of the batched decode cache; step() advances every active
slot one token (greedy). Finished slots (EOS / max_len) free up for the
queue. This is the serving analogue of the paper's offload: ONE compiled
decode program serves the whole batch per step, with all schedule work
(attention over sharded caches, SSM state updates) inside it.

With a ``collective_client`` (a :class:`repro.service.ServiceClient`), each
step also posts its batched slot-statistics reduction — active slots, tokens
emitted, finished requests — as an ALLREDUCE descriptor to the shared
offload service instead of reducing locally: the serving engine becomes one
more tenant of the broker, its per-step reductions coalescing with every
other stream's requests. Tickets are collected asynchronously; call
:meth:`collect_service_stats` to resolve them into serving totals.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelApi
from repro.sharding.specs import Topology, use_topology


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (prompt_len,)
    max_new_tokens: int = 32
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        api: ModelApi,
        params,
        topo: Topology,
        *,
        batch_size: int = 4,
        max_len: int = 256,
        eos_id: int = 1,
        collective_client=None,
    ):
        self.api = api
        self.params = params
        self.topo = topo
        self.B = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        with use_topology(topo):
            self.cache = api.init_cache(batch_size, max_len)
        self.slots: List[Optional[Request]] = [None] * batch_size
        self.lengths = np.zeros(batch_size, dtype=np.int32)
        self.cur_tokens = np.zeros((batch_size, 1), dtype=np.int32)
        self.queue: List[Request] = []
        self._decode = None
        # offload-service tenancy: the per-step slot-stats reduction is a
        # wire-encoded ALLREDUCE over the slot axis (each slot plays the
        # role of a rank), submitted async and resolved on demand
        self._collective = collective_client
        self._stat_tickets: List = []
        self._stat_totals = np.zeros(3, dtype=np.float64)
        self._stat_steps = 0
        self._stats_desc = (
            None
            if collective_client is None
            else collective_client.broker.make_descriptor(
                "ALLREDUCE", p=batch_size, payload_bytes=3 * 4, op="sum"
            ).encode()
        )

    # -------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.B):
            if self.slots[slot] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_into(slot, req)
                self.slots[slot] = req

    def _prefill_into(self, slot: int, req: Request) -> None:
        """Run prompt prefill batch-of-1 and splice cache rows into the slot."""
        plen = len(req.prompt)
        tokens = jnp.asarray(req.prompt, jnp.int32)[None, :]
        with use_topology(self.topo):
            last_logits, pcache = self.api.prefill(
                self.params, {"tokens": tokens}
            )

        def splice(big, small):
            # big: (L, B, S_max, ...) or mamba states; small: (L, 1, plen,...)
            if big.ndim >= 3 and small.shape[2] != big.shape[2] and small.ndim == big.ndim:
                pad = [(0, 0)] * small.ndim
                pad[2] = (0, big.shape[2] - small.shape[2])
                small = jnp.pad(small.astype(big.dtype), pad)
            return jax.lax.dynamic_update_index_in_dim(
                big, small[:, 0].astype(big.dtype), slot, axis=1
            )

        self.cache = jax.tree.map(splice, self.cache, pcache)
        first = np.asarray(jnp.argmax(last_logits[:, -1], -1)).astype(np.int32)
        self.cur_tokens[slot, 0] = int(first[0])
        self.lengths[slot] = plen
        req.generated.append(int(first[0]))

    # ---------------------------------------------------------------- step
    def step(self) -> Dict[int, int]:
        """Advance every active slot one token. Returns {rid: token}."""
        self._admit()
        active = [s for s in range(self.B) if self.slots[s] is not None]
        if not active:
            return {}
        # one shared cache_len per compiled step: use the max; per-slot
        # correctness comes from each slot's own written region (padding
        # regions score ~0 after the causal mask)
        clen = int(self.lengths.max())
        with use_topology(self.topo):
            if self._decode is None:
                self._decode = jax.jit(self.api.decode_step)
            nxt, self.cache = self._decode(
                self.params,
                jnp.asarray(self.cur_tokens),
                self.cache,
                jnp.asarray(clen, jnp.int32),
            )
        nxt = np.asarray(nxt)
        out: Dict[int, int] = {}
        for s in active:
            req = self.slots[s]
            tok = int(nxt[s, 0])
            req.generated.append(tok)
            out[req.rid] = tok
            self.lengths[s] += 1
            if (
                tok == self.eos_id
                or len(req.generated) >= req.max_new_tokens
                or self.lengths[s] >= self.max_len - 1
            ):
                req.done = True
                self.slots[s] = None
            else:
                self.cur_tokens[s, 0] = tok
        if self._collective is not None:
            self._post_step_stats(active)
        return out

    # ------------------------------------------------- service tenancy
    def _post_step_stats(self, active) -> None:
        """Post this step's batched slot-stats reduction to the offload
        service: per-slot [active, tokens_emitted, finished] rows, summed
        over the slot axis by one shared ALLREDUCE dispatch."""
        stats = np.zeros((self.B, 3), dtype=np.float32)
        for s in active:
            stats[s, 0] = 1.0  # slot was active
            stats[s, 1] = 1.0  # one token emitted per active slot per step
            if self.slots[s] is None:  # freed this step => request finished
                stats[s, 2] = 1.0
        self._stat_tickets.append(
            self._collective.submit(self._stats_desc, jnp.asarray(stats))
        )
        # fold already-completed tickets into the running totals so a
        # long-lived serving process never accumulates unbounded tickets
        still_pending = []
        for ticket in self._stat_tickets:
            if ticket.done():
                self._fold_ticket(ticket, timeout=0.0)
            else:
                still_pending.append(ticket)
        self._stat_tickets = still_pending

    def _fold_ticket(self, ticket, timeout: float) -> None:
        reduced = np.asarray(ticket.result(timeout))
        self._stat_totals += reduced[0]  # every row holds the slot-axis sum
        self._stat_steps += 1

    def collect_service_stats(self, timeout: float = 30.0) -> Dict[str, int]:
        """Resolve outstanding stat tickets and return the serving totals
        accumulated since the last call."""
        for ticket in self._stat_tickets:
            self._fold_ticket(ticket, timeout)
        self._stat_tickets = []
        out = {
            "service_steps": self._stat_steps,
            "slot_steps": int(self._stat_totals[0]),
            "tokens_emitted": int(self._stat_totals[1]),
            "requests_finished": int(self._stat_totals[2]),
        }
        self._stat_totals = np.zeros(3, dtype=np.float64)
        self._stat_steps = 0
        return out

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                return
            self.step()
