from repro.serving.engine import Request, ServeEngine
