"""Synthetic sharded data pipeline with scan-based packing.

Deterministic seeded token streams, sharded per host (host_id/host_count
emulate the multi-host layout this container can't spawn). Variable-length
documents are packed into fixed-length training sequences using EXCLUSIVE
PREFIX-SCAN offsets — the paper's primitive running in the data layer (via the
Pallas prefix-scan kernel path on-device, numpy here on the host side).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    host_count: int = 1
    mean_doc_len: int = 512
    pad_id: int = 0
    eos_id: int = 1


def document_stream(cfg: DataConfig) -> Iterator[np.ndarray]:
    """Infinite stream of variable-length synthetic documents for this host.

    Documents are incrementing mod-vocab runs from a random start (a bigram-
    learnable structure, so training losses demonstrably decrease) with 10%
    uniform noise tokens (so the loss floor is not zero).
    """
    rng = np.random.default_rng(cfg.seed * 1000003 + cfg.host_id)
    lo, hi = 2, cfg.vocab_size
    span = hi - lo
    while True:
        n = int(np.clip(rng.geometric(1.0 / cfg.mean_doc_len), 8, 8 * cfg.mean_doc_len))
        start = rng.integers(0, span)
        doc = (lo + (start + np.arange(n)) % span).astype(np.int32)
        noise = rng.random(n) < 0.1
        doc[noise] = rng.integers(lo, hi, size=int(noise.sum()), dtype=np.int32)
        doc[-1] = cfg.eos_id
        yield doc


def pack_documents(docs: List[np.ndarray], seq_len: int, pad_id: int = 0):
    """Pack docs into one (n_seqs, seq_len) matrix via exclusive-scan offsets.

    Offsets of each document in the flat packed stream are the exclusive
    prefix sum of document lengths — MPI_Exscan semantics on the host.
    Returns (packed, segment_ids) where segment_ids mark document boundaries.
    """
    lens = np.array([len(d) for d in docs], dtype=np.int64)
    offsets = np.concatenate([[0], np.cumsum(lens)[:-1]])  # exclusive scan
    total = int(lens.sum())
    n_seqs = -(-total // seq_len)
    flat = np.full(n_seqs * seq_len, pad_id, dtype=np.int32)
    seg = np.zeros(n_seqs * seq_len, dtype=np.int32)
    for i, (d, off) in enumerate(zip(docs, offsets)):
        flat[off : off + len(d)] = d
        seg[off : off + len(d)] = i + 1
    return flat.reshape(n_seqs, seq_len), seg.reshape(n_seqs, seq_len)


def batches(cfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite {tokens, labels} batches (this host's slice of global batch)."""
    local_batch = cfg.global_batch // cfg.host_count
    assert local_batch * cfg.host_count == cfg.global_batch, (
        cfg.global_batch, cfg.host_count)
    stream = document_stream(cfg)
    buf: List[np.ndarray] = []
    ready: List[np.ndarray] = []
    while True:
        while len(ready) < local_batch:
            # accumulate enough docs to pack at least one full row
            need = cfg.seq_len + 1
            acc = 0
            buf = []
            while acc < need * 2:
                d = next(stream)
                buf.append(d)
                acc += len(d)
            packed, _ = pack_documents(buf, cfg.seq_len + 1, cfg.pad_id)
            ready.extend(list(packed))
        rows = np.stack(ready[:local_batch])
        ready = ready[local_batch:]
        yield {
            "tokens": rows[:, :-1].astype(np.int32),
            "labels": rows[:, 1:].astype(np.int32),
        }
