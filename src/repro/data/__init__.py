from repro.data.pipeline import DataConfig, batches, document_stream, pack_documents
