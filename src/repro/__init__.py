"""repro: the paper (MPI_Scan offload) as a JAX/TPU framework."""
