#!/usr/bin/env bash
# One-entry-point CI gate: tier-1 test suite + offload-engine smoke benchmark
# + the multi-tenant service check.
#
#   bash scripts/ci.sh           # full tier-1 + offload/planner/service smoke
#
# The smoke benchmark (benchmarks.run --smoke) runs a budgeted autotuning grid,
# proves the descriptor schedule cache (hit/miss telemetry), executes one 3D
# planned collective end-to-end per CollType — asserting the repeat dispatch
# hits the plan cache and that telemetry exposes cache_size + per-coll
# latency — reports the tuned-vs-fixed axis split, runs a 2-step DP
# trainer on a 2x2 CPU mesh with use_offload_engine=True, asserting the
# step-2 dispatch is a plan-cache hit and that loss/grads/params are bitwise
# equal to the raw shard_map baseline (plus planner-first remesh adoption),
# drives the multi-tenant broker, asserting coalesced dispatches are
# bitwise equal to direct engine dispatch with a coalesce factor > 1, and
# proves the plan-optimizer pass pipeline: fused plans bitwise-equal to
# unfused, fewer SCAN/EXSCAN communication rounds on multi-axis meshes, and
# a profiler-sourced per-schedule device latency in the engine telemetry,
# plus the chunked-streaming check: every chunked lowering bitwise-equal to
# the unchunked schedule and the tuned chunked plan beating C=1 wall-clock
# past the payload threshold.
# The service check (repro.testing.service_check) then exercises the broker
# in driver mode on a real 2x2 mesh: 4 concurrent tenant streams, bitwise
# equality, backpressure isolation, and registry split-winner inheritance.
# The pallas check (repro.testing.pallas_check) proves the fused-Pallas
# "NIC" kernel lowering backend on a 1x8 host mesh in interpret mode:
# SCAN/EXSCAN/BARRIER and both FUSED_SCAN_TOTAL forms bitwise-equal to
# the op-per-round lower_spmd reference, and non-zero-identity operators
# cleanly rejected by the capability gate (the engine's fallback path).
# The observability check (repro.testing.obs_check) proves the tracing
# layer: a traced 2x2 dispatch is bitwise-identical to the untraced one
# and yields >= 1 phase span plus the declared round spans per comm phase,
# with host+device trace merge and Prometheus rendering.
# The health check (repro.testing.health_check) proves the health stack on
# a 2x2 mesh: a synthetic 10ms delay planted on one link is attributed to
# exactly that (axis, src, dst) by the per-link straggler detector, a
# deadline-miss SLO burn-rate alert fires, every probed/driver dispatch
# stays bitwise-identical to the sim baseline, and the flight-recorder
# dump is valid JSON.
# The chaos check (repro.testing.chaos_check) proves the reliability
# stack on a 2x2 mesh: all five CollTypes bitwise-correct through seeded
# 5% message drop+corrupt chaos purely via retries, a poisoned queued
# payload quarantined by group bisection while clean neighbors complete,
# and the circuit breaker tripping into the raw-lax reference under 100%
# loss then recovering through a half-open probe, with /healthz tracking
# both transitions. benchmarks.obs_overhead then measures the
# flight-recorder cost on the smoke dispatch path, and
# benchmarks.reliability_overhead measures the reliable-dispatch happy
# path (checksums + retry bookkeeping) against the raw broker path.
# Finally, benchmarks.check_regression diffs the freshly-written BENCH
# artifacts against the committed baselines (snapshotted BEFORE the
# smoke run overwrites them): lost grid rows, lost bitwise/coalesce
# proofs, > 2x latency drift, flight-recorder overhead past 2%, or
# reliability overhead past 2% fail CI. Regressions in the
# offload/planner/service subsystems fail CI even when no unit test
# covers them yet.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1: pytest ==="
python -m pytest -x -q

echo
echo "=== offload-engine + planner + service smoke benchmark ==="
SMOKE_OUT="$(mktemp -t repro_smoke.XXXXXX.csv)"
trap 'rm -f "$SMOKE_OUT"' EXIT
# snapshot the committed BENCH baselines before --report-json rewrites them
BASE_DIR="$(mktemp -d -t repro_bench_base.XXXXXX)"
trap 'rm -f "$SMOKE_OUT"; rm -rf "$BASE_DIR"' EXIT
cp benchmarks/BENCH_fusion.json "$BASE_DIR/BENCH_fusion.json"
cp benchmarks/BENCH_service.json "$BASE_DIR/BENCH_service.json"
cp benchmarks/BENCH_obs.json "$BASE_DIR/BENCH_obs.json"
cp benchmarks/BENCH_reliability.json "$BASE_DIR/BENCH_reliability.json"
python -m benchmarks.run --smoke --report-json | tee "$SMOKE_OUT"
grep -q "^planned_smoke_summary," "$SMOKE_OUT" \
  || { echo "CI FAIL: planned 3D smoke section missing"; exit 1; }
grep -q "^trainer_offload_summary,bitwise_equal,1,step2_cache_hit,1," "$SMOKE_OUT" \
  || { echo "CI FAIL: offloaded trainer smoke missing or not bitwise"; exit 1; }
grep -q "^service_smoke_summary,bitwise_equal,1,coalesce_gt1,1," "$SMOKE_OUT" \
  || { echo "CI FAIL: service smoke missing, not bitwise, or not coalescing"; exit 1; }
grep -q "^fusion_summary,bitwise_equal,1,rounds_reduced,1,device_latency,1," "$SMOKE_OUT" \
  || { echo "CI FAIL: plan-optimizer smoke missing, fused plan regressed the bitwise check, or rounds/device-latency not reported"; exit 1; }
grep -Eq "^chunking_check,.*,bitwise,1,win,1$" "$SMOKE_OUT" \
  || { echo "CI FAIL: chunked streaming check missing, not bitwise, or the tuned chunked plan no longer beats C=1 wall-clock"; exit 1; }
echo "fusion speedup: $(grep '^fusion_summary,' "$SMOKE_OUT")"
echo "chunked streaming: $(grep '^chunking_check,' "$SMOKE_OUT")"

echo
echo "=== multi-tenant service check (driver mode, 2x2 mesh) ==="
SVC_OUT="$(mktemp -t repro_service.XXXXXX.log)"
trap 'rm -f "$SMOKE_OUT" "$SVC_OUT"' EXIT
python -m repro.testing.service_check 2 2 | tee "$SVC_OUT"
grep -q "^service_check_summary,bitwise_equal,1,coalesce_gt1,1," "$SVC_OUT" \
  || { echo "CI FAIL: service check not bitwise or not coalescing"; exit 1; }
grep -q "^ALL-OK$" "$SVC_OUT" \
  || { echo "CI FAIL: service check did not pass"; exit 1; }

echo
echo "=== pallas lowering-backend check (fused kernel vs lower_spmd, 1x8) ==="
PAL_OUT="$(mktemp -t repro_pallas.XXXXXX.log)"
trap 'rm -f "$SMOKE_OUT" "$SVC_OUT" "$PAL_OUT"; rm -rf "$BASE_DIR"' EXIT
python -m repro.testing.pallas_check 8 | tee "$PAL_OUT"
grep -q "^pallas_check,scan:sum,p,8,bitwise,1$" "$PAL_OUT" \
  || { echo "CI FAIL: fused pallas kernel not bitwise-equal to lower_spmd"; exit 1; }
grep -q "^ALL-OK$" "$PAL_OUT" \
  || { echo "CI FAIL: pallas lowering-backend check did not pass"; exit 1; }

echo
echo "=== observability check (traced dispatch: spans + metrics + merge) ==="
OBS_OUT="$(mktemp -t repro_obs.XXXXXX.log)"
trap 'rm -f "$SMOKE_OUT" "$SVC_OUT" "$PAL_OUT" "$OBS_OUT"; rm -rf "$BASE_DIR"' EXIT
python -m repro.testing.obs_check 2 2 | tee "$OBS_OUT"
grep -q "^obs_check_summary,bitwise_equal,1," "$OBS_OUT" \
  || { echo "CI FAIL: traced dispatch not bitwise-identical"; exit 1; }
grep -q "^ALL-OK$" "$OBS_OUT" \
  || { echo "CI FAIL: observability check did not pass"; exit 1; }

echo
echo "=== health check (link attribution + SLO alerting, 2x2 mesh) ==="
HLT_OUT="$(mktemp -t repro_health.XXXXXX.log)"
trap 'rm -f "$SMOKE_OUT" "$SVC_OUT" "$PAL_OUT" "$OBS_OUT" "$HLT_OUT"; rm -rf "$BASE_DIR"' EXIT
python -m repro.testing.health_check 2 2 | tee "$HLT_OUT"
grep -q "^health_check_summary,bitwise_equal,1,.*attribution_ok,1,slo_alert,1,dump_valid,1," "$HLT_OUT" \
  || { echo "CI FAIL: health check lost bitwise equality, link attribution, SLO alerting, or the flight-recorder dump"; exit 1; }
grep -q "^ALL-OK$" "$HLT_OUT" \
  || { echo "CI FAIL: health check did not pass"; exit 1; }

echo
echo "=== chaos check (retries, bisection quarantine, breaker, 2x2 mesh) ==="
CHS_OUT="$(mktemp -t repro_chaos.XXXXXX.log)"
trap 'rm -f "$SMOKE_OUT" "$SVC_OUT" "$PAL_OUT" "$OBS_OUT" "$HLT_OUT" "$CHS_OUT"; rm -rf "$BASE_DIR"' EXIT
python -m repro.testing.chaos_check 2 2 | tee "$CHS_OUT"
grep -Eq "^chaos_check_summary,bitwise_equal,1,faults,[1-9][0-9]*,retries,[1-9][0-9]*,quarantine_ok,1,breaker_ok,1,healthz_ok,1$" "$CHS_OUT" \
  || { echo "CI FAIL: chaos check lost bitwise recovery, injected no faults, or lost quarantine/breaker/healthz behavior"; exit 1; }
grep -q "^ALL-OK$" "$CHS_OUT" \
  || { echo "CI FAIL: chaos check did not pass"; exit 1; }

echo
echo "=== flight-recorder overhead benchmark ==="
python -m benchmarks.obs_overhead

echo
echo "=== reliability overhead benchmark ==="
python -m benchmarks.reliability_overhead

echo
echo "=== benchmark regression gate (fresh BENCH vs committed baseline) ==="
REG_OUT="$(mktemp -t repro_reg.XXXXXX.log)"
trap 'rm -f "$SMOKE_OUT" "$SVC_OUT" "$PAL_OUT" "$OBS_OUT" "$HLT_OUT" "$CHS_OUT" "$REG_OUT"; rm -rf "$BASE_DIR"' EXIT
python -m benchmarks.check_regression \
  --baseline-fusion "$BASE_DIR/BENCH_fusion.json" \
  --fusion benchmarks/BENCH_fusion.json \
  --baseline-service "$BASE_DIR/BENCH_service.json" \
  --service benchmarks/BENCH_service.json \
  --baseline-obs "$BASE_DIR/BENCH_obs.json" \
  --obs benchmarks/BENCH_obs.json \
  --baseline-reliability "$BASE_DIR/BENCH_reliability.json" \
  --reliability benchmarks/BENCH_reliability.json \
  --require-per-round | tee "$REG_OUT"
grep -q "^ALL-OK$" "$REG_OUT" \
  || { echo "CI FAIL: benchmark regression gate did not pass"; exit 1; }

echo
echo "CI OK"
