#!/usr/bin/env bash
# One-entry-point CI gate: tier-1 test suite + offload-engine smoke benchmark.
#
#   bash scripts/ci.sh           # full tier-1 + ~10 s offload smoke
#
# The smoke benchmark (benchmarks.run --smoke) runs a budgeted autotuning grid
# and proves the descriptor schedule cache (hit/miss telemetry), so regressions
# in the offload subsystem fail CI even when no unit test covers them yet.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1: pytest ==="
python -m pytest -x -q

echo
echo "=== offload-engine smoke benchmark ==="
python -m benchmarks.run --smoke

echo
echo "CI OK"
