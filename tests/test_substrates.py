"""Data pipeline, optimizer, checkpoint, compression, straggler unit tests."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from repro.testing.hypothesis_compat import given, settings, strategies as st

from repro.data.pipeline import DataConfig, batches, pack_documents
from repro.checkpoint.manager import CheckpointManager
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state, lr_at
from repro.optim.compression import (
    compress_with_feedback,
    dequantize_int8,
    quantize_int8,
)
from repro.runtime.straggler import StragglerDetector
from repro.runtime.fault import plan_remesh, rescale_batch


# ------------------------------------------------------------------- data
def test_pack_documents_offsets():
    docs = [np.arange(2, 7, dtype=np.int32), np.arange(10, 13, dtype=np.int32)]
    packed, seg = pack_documents(docs, seq_len=4, pad_id=0)
    flat = packed.reshape(-1)
    assert list(flat[:5]) == [2, 3, 4, 5, 6]
    assert list(flat[5:8]) == [10, 11, 12]
    assert (seg.reshape(-1)[:5] == 1).all()
    assert (seg.reshape(-1)[5:8] == 2).all()


def test_batches_deterministic_and_sharded():
    cfg = DataConfig(vocab_size=1000, seq_len=64, global_batch=8, seed=7)
    b1 = next(batches(cfg))
    b2 = next(batches(cfg))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 64)
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # different hosts -> different data
    cfg2 = DataConfig(
        vocab_size=1000, seq_len=64, global_batch=8, seed=7,
        host_id=1, host_count=2,
    )
    b3 = next(batches(cfg2))
    assert b3["tokens"].shape == (4, 64)
    assert not np.array_equal(b1["tokens"][:4], b3["tokens"])


# -------------------------------------------------------------- optimizer
def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=5, total_steps=200, weight_decay=0.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params)

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(150):
        g = jax.grad(loss_fn)(params)
        params, opt, stats = adamw_update(g, opt, params, cfg)
    assert float(loss_fn(params)) < 1e-2
    assert np.isfinite(stats["grad_norm"])


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(lr_at(jnp.asarray(s), cfg)) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 1e-3) < 1e-9          # peak at warmup end
    assert lrs[-1] <= lrs[1]
    assert lrs[-1] >= 0.1 * 1e-3 - 1e-12      # floor


def test_grad_clipping_applied():
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    opt = init_opt_state(params)
    huge = {"w": jnp.full(4, 1e6)}
    _, _, stats = adamw_update(huge, opt, params, cfg)
    assert float(stats["grad_norm"]) > 1e5  # raw norm reported pre-clip


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.int32)}}
    for step in (1, 2, 3, 4):
        mgr.save(step, jax.tree.map(lambda x, s=step: x + s, tree))
    assert mgr.all_steps() == [3, 4]  # keep=2 GC'd older
    step, restored = mgr.restore(tree)
    assert step == 4
    np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(tree["a"]) + 4)


def test_checkpoint_async_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=True)
    tree = {"w": jnp.ones((16, 16))}
    mgr.save(10, tree)
    mgr.wait()
    step, restored = mgr.restore(tree)
    assert step == 10
    np.testing.assert_allclose(np.asarray(restored["w"]), 1.0)


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.ones(3)}
    mgr.save(5, tree)
    # a crashed partial write leaves only .tmp — must be invisible
    (tmp_path / ".tmp_step_9").mkdir()
    assert mgr.latest_step() == 5


# ------------------------------------------------------------ compression
@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 1e3))
def test_quantize_roundtrip_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(64,)).astype(np.float32) * scale)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    amax = float(jnp.max(jnp.abs(x)))
    assert err.max() <= amax / 127.0 + 1e-6


def test_error_feedback_preserves_signal():
    """With EF, repeated compression of a constant gradient is unbiased:
    the accumulated transmitted value converges to the true gradient."""
    g = {"w": jnp.asarray([0.001, 0.5, -0.3])}
    err = None
    sent = np.zeros(3)
    for _ in range(64):
        q, s, err = compress_with_feedback(g, err)
        sent += np.asarray(dequantize_int8(q["w"], s["w"]))
    np.testing.assert_allclose(sent / 64, np.asarray(g["w"]), atol=2e-3)


# --------------------------------------------------------------- runtime
def test_straggler_detector_flags_spikes():
    det = StragglerDetector(warmup=2, threshold=2.0, evict_after=2)
    verdicts = []
    times = [1.0, 1.0, 1.0, 1.0, 1.0, 5.0, 5.0, 1.0]
    for i, t in enumerate(times):
        verdicts.append(det.observe(i, t))
    assert verdicts[5]["flagged"] and verdicts[6]["flagged"]
    assert verdicts[6]["evict"]
    assert not verdicts[7]["flagged"]


def test_plan_remesh():
    assert plan_remesh(16, 16, lost_hosts=1) == (8, 16)
    assert plan_remesh(16, 16, lost_hosts=0) == (16, 16)
    assert plan_remesh(2, 16, lost_hosts=2) is None
    assert rescale_batch(256, 16, 8) == 128
