"""End-to-end service check on a real 2x2 device mesh (subprocess: the
device count must be fixed before jax initializes). Scenarios: >= 4
concurrent tenant streams through the broker's driver-mode dispatch, bitwise
equality against direct per-client engine dispatch, measured coalesce
factor > 1, backpressure isolation, registry split-winner inheritance, and
the deadline flush for a lone request."""

import re


def test_service_end_to_end_2x2(subprocess_runner):
    out = subprocess_runner("repro.testing.service_check", "2", "2")
    m = re.search(
        r"service_check_summary,bitwise_equal,1,coalesce_gt1,1,"
        r"coalesce_factor,([0-9.]+)",
        out,
    )
    assert m, f"summary row missing or failing:\n{out[-2000:]}"
    assert float(m.group(1)) > 1.0
