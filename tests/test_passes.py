"""Plan-optimizer pass-pipeline tests: fused-vs-unfused bitwise equivalence
for every CollType / axis order / operator family, size-1 dead-phase
regression, fusion structure and round accounting, the optimized-plan cache
key (compile-count shrink), the fusion-winner tuning hook, the broker's
mixed-flag guard, and the profiler-sourced device telemetry.

Bitwise equality across different combine trees requires exact arithmetic;
value strategies stick to integers and powers of two (and, for flash, a
shared running max so every rescale factor is exactly 1.0), exactly like
the planner tests.
"""

import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    SSD,
    CollType,
    get_operator,
    sim_scan,
)
from repro.core.selector import set_active_tuning
from repro.offload import (
    OffloadEngine,
    PhaseKind,
    TuningCache,
    build_plan,
    choose_optimization,
    eliminate_dead_phases,
    fuse_scan_total,
    lower_sim,
    optimize_plan,
    plan_comm_rounds,
    plan_layout_moves,
    tune_fusion,
)
from repro.service import DescriptorBroker
from repro.testing.hypothesis_compat import given, settings, strategies as st

MESHES = [(2, 4), (4, 2), (2, 2), (3, 2), (2, 2, 2), (2, 3, 2), (1, 4),
          (2, 1, 2), (4,), (1,)]


@pytest.fixture(autouse=True)
def _no_active_tuning():
    set_active_tuning(None)
    yield
    set_active_tuning(None)


def _orders(k, idx):
    import itertools

    perms = list(itertools.permutations(range(k)))
    return perms[idx % len(perms)]


# ------------------------------------------------- bitwise: fused == unfused


@settings(max_examples=40, deadline=None)
@given(
    mesh_idx=st.integers(0, len(MESHES) - 1),
    coll_idx=st.integers(0, len(CollType) - 1),
    order_idx=st.integers(0, 5),
    seed=st.integers(0, 10_000),
)
def test_optimized_bitwise_equals_unfused_all_colltypes(
    mesh_idx, coll_idx, order_idx, seed
):
    """Every CollType, every 1-3-axis mesh/order: the optimized plan's
    result equals the unoptimized plan's AND the flat reference, bit for
    bit (integer payloads)."""
    sizes = MESHES[mesh_idx]
    coll = list(CollType)[coll_idx].name
    order = _orders(len(sizes), order_idx)
    p = int(np.prod(sizes))
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-6, 7, size=(p, 5)).astype(np.float32))
    root = seed % p
    raw = build_plan(coll, sizes, "sum", 20, order=order, root=root)
    opt = optimize_plan(raw)
    arg = None if coll == "BARRIER" else x
    got_raw = np.asarray(lower_sim(raw)(arg))
    got_opt = np.asarray(lower_sim(opt)(arg))
    np.testing.assert_array_equal(got_opt, got_raw)


@settings(max_examples=24, deadline=None)
@given(
    mesh_idx=st.integers(0, 4),
    inclusive=st.booleans(),
    order_idx=st.integers(0, 5),
    seed=st.integers(0, 10_000),
)
def test_optimized_ssd_bitwise(mesh_idx, inclusive, order_idx, seed):
    """Non-commutative SSD (decay, state) recurrence: fused == unfused
    bitwise for inclusive and exclusive scans, every axis order."""
    sizes = [(2, 4), (4, 2), (2, 2, 2), (3, 2), (2, 1, 2)][mesh_idx]
    order = _orders(len(sizes), order_idx)
    p = int(np.prod(sizes))
    rng = np.random.default_rng(seed)
    a = jnp.asarray(
        rng.choice([0.5, 1.0, 2.0], size=(p, 4)).astype(np.float32)
    )
    b = jnp.asarray(rng.integers(-4, 5, size=(p, 4)).astype(np.float32))
    coll = "SCAN" if inclusive else "EXSCAN"
    raw = build_plan(coll, sizes, SSD, 32, order=order)
    opt = optimize_plan(raw)
    ra, rb = lower_sim(raw, SSD)((a, b))
    oa, ob = lower_sim(opt, SSD)((a, b))
    np.testing.assert_array_equal(np.asarray(oa), np.asarray(ra))
    np.testing.assert_array_equal(np.asarray(ob), np.asarray(rb))


@settings(max_examples=16, deadline=None)
@given(
    mesh_idx=st.integers(0, 3),
    inclusive=st.booleans(),
    m_val=st.integers(-3, 3),
    seed=st.integers(0, 10_000),
)
def test_optimized_flash_bitwise(mesh_idx, inclusive, m_val, seed):
    """Flash-attention combine (m, l, o): with a shared running max every
    rescale is exp(0) == 1.0 exactly, so fused == unfused bitwise."""
    sizes = [(2, 4), (4, 2), (2, 2, 2), (2, 3)][mesh_idx]
    p = int(np.prod(sizes))
    flash = get_operator("flash")
    rng = np.random.default_rng(seed)
    m = jnp.full((p, 4), float(m_val), jnp.float32)
    l = jnp.asarray(rng.integers(1, 6, size=(p, 4)).astype(np.float32))
    o = jnp.asarray(rng.integers(-5, 6, size=(p, 4)).astype(np.float32))
    coll = "SCAN" if inclusive else "EXSCAN"
    raw = build_plan(coll, sizes, flash, 48, order="auto")
    opt = optimize_plan(raw)
    got_raw = lower_sim(raw, flash)((m, l, o))
    got_opt = lower_sim(opt, flash)((m, l, o))
    for g, w in zip(got_opt, got_raw):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ------------------------------------------------------ pass structure


def test_fusion_produces_fused_phases_and_fewer_rounds():
    raw = build_plan("SCAN", (2, 4), "sum", 16, order=(0, 1))
    opt = optimize_plan(raw)
    assert opt.optimized and not raw.optimized
    kinds = [ph.kind for ph in opt.phases]
    assert kinds[0] == PhaseKind.FUSED_SCAN_TOTAL
    fused = opt.phases[0]
    assert fused.dst == "y" and fused.dst2 == "t" and fused.src == ("x",)
    assert plan_comm_rounds(opt) < plan_comm_rounds(raw)
    # 3-axis SCAN fuses at two ladder levels
    opt3 = optimize_plan(build_plan("SCAN", (2, 2, 2), "sum", 16,
                                    order=(0, 1, 2)))
    assert sum(
        ph.kind == PhaseKind.FUSED_SCAN_TOTAL for ph in opt3.phases
    ) == 2
    # EXSCAN reduces rounds even on the CI 2x2 mesh
    raw22 = build_plan("EXSCAN", (2, 2), "sum", 16, order=(0, 1))
    assert plan_comm_rounds(optimize_plan(raw22)) < plan_comm_rounds(raw22)


def test_fusion_requires_same_source_register():
    """A TOTAL reading a different register than the SCAN must not fuse."""
    raw = build_plan("SCAN", (2, 4), "sum", 16, order=(0, 1))
    scan_ph = raw.phases[0]
    hacked = dataclasses.replace(
        raw,
        phases=(scan_ph,)
        + (dataclasses.replace(raw.phases[1], src=(scan_ph.dst,)),)
        + raw.phases[2:],
    )
    fused = fuse_scan_total(hacked)
    assert all(
        ph.kind != PhaseKind.FUSED_SCAN_TOTAL for ph in fused.phases
    )


def test_size_one_axes_produce_zero_phases():
    """Dead-phase elimination: no optimized phase may span a size-1 level,
    and degenerate meshes collapse to zero communication phases."""
    comm_kinds = (
        PhaseKind.SCAN, PhaseKind.TOTAL, PhaseKind.REDUCE,
        PhaseKind.BARRIER, PhaseKind.FUSED_SCAN_TOTAL,
    )
    for sizes in [(1, 4), (4, 1), (2, 1, 2), (1, 1), (1,), (1, 1, 3)]:
        for coll in [c.name for c in CollType]:
            opt = optimize_plan(
                build_plan(coll, sizes, "sum", 16,
                           order=tuple(range(len(sizes))))
            )
            for ph in opt.phases:
                if ph.level >= 0:
                    assert opt.logical_sizes[ph.level] > 1, (coll, sizes, ph)
    # a (1, 4) scan is exactly the (4,) scan: one communication phase
    opt = optimize_plan(build_plan("SCAN", (1, 4), "sum", 16, order=(0, 1)))
    assert len(opt.phases) == 1 and opt.phases[0].kind == PhaseKind.SCAN
    # an all-ones mesh has no communication at all
    for coll in [c.name for c in CollType]:
        opt = optimize_plan(
            build_plan(coll, (1, 1), "sum", 16, order=(0, 1))
        )
        assert not [p for p in opt.phases if p.kind in comm_kinds], coll
    # ... and an all-ones EXSCAN still materializes the identity
    opt = optimize_plan(build_plan("EXSCAN", (1, 1), "sum", 16, order=(0, 1)))
    assert [ph.kind for ph in opt.phases] == [PhaseKind.IDENTITY]
    x = jnp.asarray([[3.0, 4.0]])
    np.testing.assert_array_equal(np.asarray(lower_sim(opt)(x)), 0.0)


def test_optimize_plan_idempotent_and_validates_pass_names():
    raw = build_plan("EXSCAN", (2, 2, 2), "sum", 16, order=(0, 1, 2))
    opt = optimize_plan(raw)
    again = optimize_plan(opt)
    assert again.phases == opt.phases and again.result == opt.result
    with pytest.raises(ValueError, match="unknown passes"):
        optimize_plan(raw, passes=("nope",))
    # dead-phase elimination alone keeps the plan unoptimized (no wire flag)
    dpe = eliminate_dead_phases(raw)
    assert not dpe.optimized


def test_describe_renders_fused_phases_and_per_plan_permute_chain():
    opt = optimize_plan(build_plan("SCAN", (2, 2, 2), "sum", 16,
                                   order=(0, 1, 2)))
    text = opt.describe()
    assert "[optimized]" in text
    assert "fused_scan_total" in text and "-> y, t" in text
    assert "permute chain (once per plan" in text
    # view sharing: the threaded chain beats the per-phase front-and-back
    # chain on the raw plan (SCAN + TOTAL share their operand's view) and
    # never exceeds it on the fused plan
    raw = build_plan("SCAN", (2, 2, 2), "sum", 16, order=(0, 1, 2))
    threaded_raw = plan_layout_moves(dataclasses.replace(raw, optimized=True))
    assert len(threaded_raw) < len(plan_layout_moves(raw))
    unthreaded_opt = plan_layout_moves(
        dataclasses.replace(opt, optimized=False)
    )
    assert len(plan_layout_moves(opt)) <= len(unthreaded_opt)
    # unoptimized plans keep the classic per-phase rendering
    raw_text = build_plan("SCAN", (2, 2), "sum", 16, order=(0, 1)).describe()
    assert "permute chain" not in raw_text


# ----------------------------------------------- engine: flag + cache key


def test_engine_optimized_dispatch_matches_and_dedups_compiles():
    eng = OffloadEngine()
    p = 8
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(-5, 6, size=(p, 6)).astype(np.float32))
    want = np.asarray(sim_scan(x, "sum", p, algorithm="hillis_steele"))
    d_opt = eng.make_descriptor(
        "SCAN", axes=(2, 2, 2), payload_bytes=24, op="sum", optimize=True
    )
    assert d_opt.optimized
    assert len(d_opt.encode()) == 16
    np.testing.assert_array_equal(np.asarray(eng.offload(d_opt, x)), want)
    # optimized vs raw are distinct compiled schedules
    d_raw = dataclasses.replace(d_opt, optimized=False)
    np.testing.assert_array_equal(np.asarray(eng.offload(d_raw, x)), want)
    assert eng.telemetry.misses == 2
    # same optimized plan from another comm_id: cache HIT, no new compile
    np.testing.assert_array_equal(
        np.asarray(eng.offload(dataclasses.replace(d_opt, comm_id=7), x)),
        want,
    )
    assert (eng.telemetry.misses, eng.telemetry.compiles) == (2, 2)
    assert eng.telemetry.hits == 1
    # (2,4) split (1,0) and (4,2) split (0,1) share one logical plan
    e2 = OffloadEngine()
    da = e2.make_descriptor("SCAN", axes=(2, 4), payload_bytes=24,
                            op="sum", split=(1, 0), optimize=True)
    db = e2.make_descriptor("SCAN", axes=(4, 2), payload_bytes=24,
                            op="sum", split=(0, 1), optimize=True)
    ya = np.asarray(e2.offload(da, x))
    yb = np.asarray(e2.offload(db, x))
    np.testing.assert_array_equal(ya, yb)
    assert (e2.telemetry.misses, e2.telemetry.hits) == (1, 1)


def test_engine_clear_drops_plan_memos():
    eng = OffloadEngine()
    x = jnp.ones((4, 2), jnp.float32)
    d = eng.make_descriptor("SCAN", axes=(2, 2), payload_bytes=8,
                            op="sum", optimize=True)
    eng.offload(d, x)
    assert eng._plan_memo and eng._plans
    eng.clear()
    assert not eng._plan_memo and not eng._plans
    assert eng.telemetry.cache_clears == 1


# ------------------------------------------------ tuning: fusion winners


def test_choose_optimization_prefers_measured_winner():
    sizes, payload = (2, 4), 1024
    # cost model says optimize (fewer rounds at equal-or-better cost)
    assert choose_optimization("EXSCAN", sizes, payload) is True
    # a measured winner saying "unfused" overrides the model
    cache = TuningCache(backend="synthetic")
    cache.record_fusion("exscan", sizes, True, payload, 9e-6)
    cache.record_fusion("exscan", sizes, False, payload, 1e-6)
    cache.activate()
    assert choose_optimization("EXSCAN", sizes, payload) is False
    # nearby payloads snap to the same winner; untuned shapes fall back
    assert choose_optimization("EXSCAN", sizes, 2048) is False
    assert choose_optimization("EXSCAN", (2, 2, 2), payload) is True
    set_active_tuning(None)
    assert choose_optimization("EXSCAN", sizes, payload) is True


def test_tune_fusion_records_both_forms_and_roundtrips(tmp_path):
    cache = tune_fusion(
        topologies=[(2, 2)], payloads=(256,), colls=("scan",), iters=1
    )
    assert ("scan", (2, 2), 256) in cache.fusion_winners
    forms = {
        m.optimized
        for m in cache.fusion_measurements
        if (m.coll, m.sizes, m.payload_bytes) == ("scan", (2, 2), 256)
    }
    assert forms == {False, True}
    path = cache.save(tmp_path / "table.json")
    loaded = TuningCache.load(path)
    assert loaded.fusion_winners == cache.fusion_winners
    # merge keeps the lower measurement per (coll, sizes, flag, payload)
    other = TuningCache(backend=cache.backend)
    other.record_fusion("scan", (2, 2), True, 256, 0.0)
    merged = loaded.merge(other)
    assert merged.fusion_winner("scan", (2, 2), 256) is True


# --------------------------------------------------- broker: mixed flags


def test_broker_rejects_mixed_optimizer_flag_groups():
    broker = DescriptorBroker(OffloadEngine())
    client = broker.client("t0")
    x = jnp.ones((4, 2), jnp.float32)
    d_opt = broker.make_descriptor(
        "ALLREDUCE", axes=(2, 2), payload_bytes=8, op="sum", optimize=True
    )
    d_raw = dataclasses.replace(d_opt, optimized=False)
    # normal grouping never mixes: the flag is in the normalized words
    t1 = client.submit(d_opt.encode(), x)
    t2 = client.submit(d_raw.encode(), x)
    broker.drain()
    np.testing.assert_array_equal(
        np.asarray(t1.result(10)), np.asarray(t2.result(10))
    )
    snap = broker.telemetry.snapshot()
    assert snap["flushes"] >= 2  # two groups, not one fused dispatch
    # the defensive guard on a hand-built mixed group fails the tickets
    import time

    from repro.service.broker import _Request, ServiceTicket

    now = time.monotonic()
    reqs = [
        _Request("t0", d, x, ServiceTicket("t0", i), now, now, None)
        for i, d in enumerate((d_opt, d_raw))
    ]
    broker._dispatch_group(reqs)
    for r in reqs:
        with pytest.raises(ValueError, match="mixed plan-optimizer"):
            r.ticket.result(1)
    client.close()


# ------------------------------------------------ SPMD (real 2x2 mesh)


def test_fusion_spmd_driver_check(subprocess_runner):
    """Driver + spmd mode on a real 2x2 device mesh: optimized descriptors
    bitwise vs raw and vs flat for all five CollTypes, fused lower_spmd
    inside shard_map, and profiler-sourced device telemetry."""
    out = subprocess_runner("repro.testing.fusion_check", "2", "2")
    assert "fusion_check_summary,bitwise_equal,1,device_latency,1" in out


# ------------------------------------------- telemetry: device-side source


def test_record_device_latency_snapshot_fields():
    eng = OffloadEngine()
    x = jnp.ones((4, 2), jnp.float32)
    d = eng.make_descriptor("SCAN", p=4, payload_bytes=8)
    eng.offload(d, x)
    snap = eng.telemetry.snapshot()
    assert snap["latency_source_by_coll"] == {"scan": "wall"}
    assert snap["device_latency_by_coll_us"] == {}
    eng.telemetry.record_device_latency("scan", 5e-6, source="profiler")
    eng.telemetry.record_device_latency("scan", 7e-6, source="profiler")
    snap = eng.telemetry.snapshot()
    assert snap["latency_source_by_coll"]["scan"] == "profiler"
    assert snap["device_latency_by_coll_us"]["scan"] == pytest.approx(6.0)
    # a wall fallback never demotes an existing profiler source — nor
    # dilutes its mean: the labeled number stays purely device-side
    eng.telemetry.record_device_latency("scan", 9e-6, source="wall")
    snap = eng.telemetry.snapshot()
    assert snap["latency_source_by_coll"]["scan"] == "profiler"
    assert snap["device_latency_by_coll_us"]["scan"] == pytest.approx(6.0)
    # ... and the first profiler sample evicts earlier wall fallbacks
    eng.telemetry.record_device_latency("allreduce", 100e-6, source="wall")
    eng.telemetry.record_device_latency("allreduce", 2e-6, source="profiler")
    snap = eng.telemetry.snapshot()
    assert snap["latency_source_by_coll"]["allreduce"] == "profiler"
    assert snap["device_latency_by_coll_us"]["allreduce"] == pytest.approx(
        2.0
    )
