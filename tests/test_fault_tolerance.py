"""Fault-tolerance integration: checkpoint/restart + failure recovery.

Runs the real Trainer on a tiny model, injects failures mid-run, and asserts
the loop recovers from the latest checkpoint and keeps making progress.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, batches
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime.fault import FailureInjector
from repro.runtime.train_loop import Trainer, TrainerConfig
from repro.sharding.specs import Topology


def _make_trainer(tmp_path, fail_at=(), steps_shape=(4, 32), exc_factory=None):
    cfg = get_config("smollm_360m").reduced()
    api = build_model(cfg)
    B, S = steps_shape
    shape = ShapeConfig("tiny", S, B, "train")
    data = batches(DataConfig(vocab_size=cfg.vocab_size, seq_len=S, global_batch=B, seed=1))
    topo = Topology(mesh=None)
    tcfg = TrainerConfig(
        ckpt_dir=str(tmp_path), ckpt_every=5, keep_ckpts=2,
        async_ckpt=False, max_retries=3,
    )
    injector = FailureInjector(fail_at=tuple(fail_at), exc_factory=exc_factory)
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100)
    return Trainer(api, topo, shape, data, tcfg, opt, injector)


def test_loss_decreases(tmp_path):
    tr = _make_trainer(tmp_path)
    params, opt = tr.init_state()
    params, opt, hist = tr.run(params, opt, num_steps=25)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first, (first, last)


def test_recovery_from_injected_failure(tmp_path):
    tr = _make_trainer(tmp_path, fail_at=(12,))
    params, opt = tr.init_state()
    params, opt, hist = tr.run(params, opt, num_steps=20)
    steps = [h["step"] for h in hist]
    # failure at 12 -> restored from ckpt at 10 -> steps 10,11 re-run
    assert steps.count(11) >= 2 or steps.count(10) >= 2
    assert max(steps) == 19
    assert len(tr.remesh_events) == 1
    # training still progressed
    assert np.mean([h["loss"] for h in hist[-3:]]) < np.mean(
        [h["loss"] for h in hist[:3]]
    )


def test_resume_from_checkpoint(tmp_path):
    tr = _make_trainer(tmp_path)
    params, opt = tr.init_state()
    params, opt, _ = tr.run(params, opt, num_steps=10)
    # new trainer instance = process restart; resumes at step 10
    tr2 = _make_trainer(tmp_path)
    p2, o2 = tr2.init_state(seed=99)  # different init; must be overwritten
    start, p2, o2 = tr2.maybe_restore(
        jax.tree.map(np.asarray, p2), jax.tree.map(np.asarray, o2)
    )
    assert start == 10
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(p2)[0], np.float32),
        np.asarray(jax.tree.leaves(params)[0], np.float32),
        atol=1e-6,
    )


def test_multiple_failures_exhaust_retries(tmp_path):
    tr = _make_trainer(tmp_path, fail_at=(3, 4, 5, 6, 7, 8, 9))
    params, opt = tr.init_state()
    # every retry fails again at the next step; must eventually raise
    with pytest.raises(Exception):
        tr.run(params, opt, num_steps=20)


def test_recovery_from_jax_runtime_error(tmp_path):
    """The docstring's promise: not just SimulatedFailure — a collective
    error from the jax runtime-error family triggers the same recovery."""
    tr = _make_trainer(
        tmp_path,
        fail_at=(7,),
        exc_factory=lambda step: jax.errors.JaxRuntimeError(
            f"DEADLINE_EXCEEDED: all-reduce hung at step {step}"
        ),
    )
    params, opt = tr.init_state()
    params, opt, hist = tr.run(params, opt, num_steps=12)
    steps = [h["step"] for h in hist]
    assert max(steps) == 11  # reached the end despite the runtime error
    assert len(tr.remesh_events) == 1
    assert "DEADLINE_EXCEEDED" in tr.remesh_events[0]["err"]


def test_non_failure_runtime_errors_propagate(tmp_path):
    """An XLA runtime error whose status code marks a caller/resource
    problem (OOM, bad shapes) must not be masked by a remesh+rollback."""
    tr = _make_trainer(
        tmp_path,
        fail_at=(2,),
        exc_factory=lambda step: jax.errors.JaxRuntimeError(
            f"RESOURCE_EXHAUSTED: out of memory at step {step}"
        ),
    )
    params, opt = tr.init_state()
    with pytest.raises(jax.errors.JaxRuntimeError, match="RESOURCE_EXHAUSTED"):
        tr.run(params, opt, num_steps=5)
    assert tr.remesh_events == []


def test_unrelated_errors_still_propagate(tmp_path):
    """Only the collective-error family is recoverable: a ValueError from a
    step must not be swallowed by the retry loop."""
    tr = _make_trainer(
        tmp_path,
        fail_at=(2,),
        exc_factory=lambda step: ValueError(f"bad batch at step {step}"),
    )
    params, opt = tr.init_state()
    with pytest.raises(ValueError, match="bad batch"):
        tr.run(params, opt, num_steps=5)
    assert tr.remesh_events == []


def test_injector_stamps_lost_hosts():
    from repro.runtime.fault import FailureInjector, SimulatedFailure

    inj = FailureInjector(fail_at=(0,), lost_hosts=3)
    with pytest.raises(SimulatedFailure) as ei:
        inj.check(0)
    assert ei.value.lost_hosts == 3
