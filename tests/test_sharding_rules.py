"""Partition-spec rules: TP layouts, divisibility fallbacks, ZeRO-1 specs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import build_model
from repro.sharding.rules import param_specs, zero1_specs, batch_specs, cache_specs
from repro.sharding.specs import Topology


class FakeMesh:
    """Shape-only stand-in so spec rules can be tested without 256 devices."""

    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)

    @property
    def devices(self):  # pragma: no cover
        raise NotImplementedError


def _topo(data=16, model=16):
    return Topology(mesh=FakeMesh({"data": data, "model": model}),
                    batch_axes=("data",), model_axis="model")


def _leaf_by_path(tree, *frags):
    found = {}

    def visit(path, leaf):
        s = jax.tree_util.keystr(path)
        if all(f in s for f in frags):
            found[s] = leaf

    jax.tree_util.tree_map_with_path(visit, tree)
    return found


@pytest.mark.parametrize("arch", ["granite_20b", "gemma3_27b", "qwen25_14b"])
def test_attention_tp_specs(arch):
    cfg = get_config(arch)
    api = build_model(cfg)
    shapes = api.param_shapes()
    specs = param_specs(shapes, cfg, _topo())
    wq = list(_leaf_by_path(specs, "attn", "wq").values())[0]
    if cfg.num_heads % 16 == 0:
        assert "model" in wq
    else:
        assert "model" not in wq
    wk = list(_leaf_by_path(specs, "attn", "wk").values())[0]
    if cfg.num_kv_heads % 16 == 0:
        assert "model" in wk
    else:
        assert "model" not in wk  # MQA (granite kv=1) -> replicated KV proj


def test_moe_expert_parallel_specs():
    cfg = get_config("deepseek_moe_16b")
    api = build_model(cfg)
    specs = param_specs(api.param_shapes(), cfg, _topo())
    w_in = list(_leaf_by_path(specs, "moe", "w_in").values())
    # experts sharded over model: stacked leaf (L, E, d, ff) -> (None, model, None, None)
    routed = [s for s in w_in if len(s) == 4]
    assert routed and all(s[1] == "model" for s in routed)
    router = list(_leaf_by_path(specs, "router").values())[0]
    assert all(e is None for e in router)


def test_mamba_sp_vs_tp_specs():
    ssm = get_config("mamba2_130m")
    specs = param_specs(build_model(ssm).param_shapes(), ssm, _topo())
    # mixer weights replicated (SP mode); embed/lm_head stay vocab-sharded
    for path, s in _leaf_by_path(specs, "mamba").items():
        assert "model" not in tuple(s), (path, "SP mamba weights replicated")

    hyb = get_config("jamba_v01_52b")
    specs = param_specs(build_model(hyb).param_shapes(), hyb, _topo())
    wz = list(_leaf_by_path(specs, "mamba", "w_z").values())[0]
    assert "model" in tuple(wz), "jamba TP mamba shards d_inner"


def test_zero1_adds_data_axis():
    cfg = get_config("smollm_360m")
    api = build_model(cfg)
    shapes = api.param_shapes()
    pspec = param_specs(shapes, cfg, _topo())
    ospec = zero1_specs(pspec, shapes, _topo())
    # embedding (V, d): vocab-sharded on model; zero1 shards d over data
    emb_p = list(_leaf_by_path(pspec, "embed").values())[0]
    emb_o = list(_leaf_by_path(ospec, "embed").values())[0]
    assert tuple(emb_p) != tuple(emb_o)
    assert "data" in tuple(emb_o)


def test_batch_and_cache_specs():
    cfg = get_config("granite_20b")
    api = build_model(cfg)
    topo = _topo()
    bshapes = {
        "tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
        "labels": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
    }
    bs = batch_specs(bshapes, topo)
    assert bs["tokens"][0] == "data"
    cache = jax.eval_shape(lambda: api.init_cache(128, 32768))
    cs = cache_specs(cache, cfg, topo)
    kspec = cs["k"]
    # granite kv=1 cannot shard heads -> sequence sharded over model
    assert kspec[2] == "model" and kspec[1] == "data"

    g3 = get_config("gemma3_27b")
    api3 = build_model(g3)
    cache3 = jax.eval_shape(lambda: api3.init_cache(128, 32768))
    cs3 = cache_specs(cache3, g3, topo)
    assert cs3["k"][3] == "model"  # kv=16 shards heads
