"""Pallas kernel validation: shape/dtype sweeps vs the jnp oracles.

Kernels execute in interpret mode on CPU (the TPU lowering is exercised by
the same pallas_call with interpret=False on real hardware).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing.hypothesis_compat import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.ops import prefix_scan, ssd_scan

SHAPES_2D = [(1, 1), (4, 100), (16, 512), (3, 257), (8, 128), (2, 1000)]
SHAPES_ND = [(2, 3, 64), (1, 2, 2, 130)]


@pytest.mark.parametrize("shape", SHAPES_2D + SHAPES_ND)
@pytest.mark.parametrize("op", ["add", "max", "mul"])
@pytest.mark.parametrize("exclusive", [False, True])
def test_prefix_scan_shapes(shape, op, exclusive):
    rng = np.random.default_rng(hash((shape, op)) % 2**31)
    if op == "mul":
        x = rng.uniform(0.5, 1.5, size=shape).astype(np.float32)
    else:
        x = rng.normal(size=shape).astype(np.float32)
    want = np.asarray(ref.ref_prefix_scan(jnp.asarray(x), op, exclusive=exclusive))
    got = np.asarray(
        prefix_scan(jnp.asarray(x), op=op, exclusive=exclusive, force_pallas=True)
    )
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prefix_scan_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 256)), dtype)
    got = prefix_scan(x, op="add", force_pallas=True)
    want = ref.ref_prefix_scan(x, "add")
    # bf16 running sums accumulate ~eps*sqrt(L) relative error and the
    # kernel's blocked association order differs from cumsum's
    tol = 1e-4 if dtype == jnp.float32 else 2.5e-1
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("blocks", [(8, 128), (16, 256), (256, 512)])
def test_prefix_scan_block_shapes(blocks):
    """Block-shape sweep: result must be block-size invariant."""
    br, bl = blocks
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(32, 1024)).astype(np.float32))
    got = prefix_scan(x, op="add", force_pallas=True, block_rows=br, block_len=bl)
    want = ref.ref_prefix_scan(x, "add")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 20),
    length=st.integers(1, 300),
    seed=st.integers(0, 2**16),
)
def test_prefix_scan_property(rows, length, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(rows, length)).astype(np.float32))
    got = prefix_scan(x, op="add", force_pallas=True)
    np.testing.assert_allclose(
        np.asarray(got), np.cumsum(np.asarray(x), -1), atol=1e-3, rtol=1e-3
    )


@pytest.mark.parametrize("shape", [(2, 64, 8), (1, 300, 4), (3, 128, 16), (1, 1, 2)])
@pytest.mark.parametrize("with_h0", [False, True])
def test_ssd_scan(shape, with_h0):
    rng = np.random.default_rng(5)
    a = jnp.asarray(rng.uniform(0.6, 1.0, size=shape).astype(np.float32))
    b = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    h0 = (
        jnp.asarray(rng.normal(size=shape[:-2] + shape[-1:]).astype(np.float32))
        if with_h0
        else None
    )
    wh, wl = ref.ref_ssd_scan(a, b, h0)
    gh, gl = ssd_scan(a, b, h0, force_pallas=True)
    np.testing.assert_allclose(np.asarray(gh), np.asarray(wh), atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(gl), np.asarray(wl), atol=2e-3, rtol=2e-3)


def test_ssd_scan_sequential_oracle():
    """ref_ssd_scan itself vs a plain python loop (oracle-of-the-oracle)."""
    rng = np.random.default_rng(7)
    a = rng.uniform(0.5, 1.0, size=(2, 37, 3)).astype(np.float32)
    b = rng.normal(size=(2, 37, 3)).astype(np.float32)
    h = np.zeros((2, 3), np.float32)
    hs = []
    for t in range(37):
        h = a[:, t] * h + b[:, t]
        hs.append(h.copy())
    want = np.stack(hs, axis=1)
    got, _ = ref.ref_ssd_scan(jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)


# ---------------------------------------------------------------------------
# flash attention kernel
# ---------------------------------------------------------------------------
from repro.kernels.ops import flash_attention  # noqa: E402


@pytest.mark.parametrize("shape", [(2, 128, 128, 64), (1, 100, 260, 32),
                                   (3, 256, 256, 128), (2, 1, 300, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_shapes(shape, causal):
    BH, Sq, Skv, D = shape
    rng = np.random.default_rng(hash((shape, causal)) % 2**31)
    q = jnp.asarray(rng.normal(size=(BH, Sq, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(BH, Skv, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(BH, Skv, D)).astype(np.float32))
    off = Skv - Sq if causal and Skv >= Sq else 0
    want = np.asarray(ref.ref_flash_attention(q, k, v, causal=causal, q_offset=off))
    got = np.asarray(flash_attention(q, k, v, causal=causal, q_offset=off,
                                     force_pallas=True))
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_window(window):
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(2, 128, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 128, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 128, 64)).astype(np.float32))
    want = np.asarray(ref.ref_flash_attention(q, k, v, causal=True, window=window))
    got = np.asarray(flash_attention(q, k, v, causal=True, window=window,
                                     force_pallas=True))
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


def test_flash_attention_block_invariance():
    rng = np.random.default_rng(10)
    q = jnp.asarray(rng.normal(size=(1, 256, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 256, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 256, 64)).astype(np.float32))
    a = np.asarray(flash_attention(q, k, v, force_pallas=True, block_q=64, block_kv=64))
    b = np.asarray(flash_attention(q, k, v, force_pallas=True, block_q=128, block_kv=256))
    np.testing.assert_allclose(a, b, atol=2e-3, rtol=2e-3)
