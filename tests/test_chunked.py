"""Chunked-streaming tests: bitwise chunked-vs-unchunked lowering for every
CollType / axis order / chunk count, the C=1 byte- and cache-key-stability
regression (a chunks=1 descriptor must encode and compile exactly like the
pre-chunking wire form), the chunk-selection pass's payload threshold, the
tuned schedule winner resolving through ``make_descriptor``, and the
algorithm-level pipeline helpers.

Bitwise equality across chunk boundaries requires exact arithmetic, so value
strategies stick to integers, exactly like the planner/passes tests.
"""

import dataclasses
import itertools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import SSD, CollType, CollectiveDescriptor, get_operator
from repro.core import algorithms as alg
from repro.core.packet import _CHUNK_WORDS, _OPT_WORDS
from repro.core.selector import set_active_tuning
from repro.offload import (
    CHUNK_CANDIDATES,
    OffloadEngine,
    TuningCache,
    build_plan,
    choose_schedule,
    lower_sim,
    optimize_plan,
    select_chunking,
)
from repro.testing.hypothesis_compat import given, settings, strategies as st

MESHES = [(8,), (2, 4), (4, 2), (2, 2, 2), (2, 2, 4), (2, 8)]
CHUNKS = (1, 2, 4, 8)


@pytest.fixture(autouse=True)
def _no_active_tuning():
    set_active_tuning(None)
    yield
    set_active_tuning(None)


def _orders(k, idx):
    perms = list(itertools.permutations(range(k)))
    return perms[idx % len(perms)]


def _int_payload(p, n, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-6, 7, size=(p, n)).astype(np.float32))


# ------------------------------------------- bitwise: chunked == unchunked


@settings(max_examples=40, deadline=None)
@given(
    mesh_idx=st.integers(0, len(MESHES) - 1),
    coll_idx=st.integers(0, len(CollType) - 1),
    chunk_idx=st.integers(0, len(CHUNKS) - 1),
    order_idx=st.integers(0, 5),
    seed=st.integers(0, 10_000),
)
def test_chunked_bitwise_equals_unchunked_all_colltypes(
    mesh_idx, coll_idx, chunk_idx, order_idx, seed
):
    """Every CollType, mesh, axis order, and C in {1,2,4,8}: the chunked
    lowering's result equals the unchunked plan's, bit for bit. CollTypes
    with no pipelined phase (REDUCE/ALLREDUCE/BARRIER) must be unaffected
    by the chunking knob, which the same comparison proves."""
    sizes = MESHES[mesh_idx]
    coll = list(CollType)[coll_idx].name
    chunks = CHUNKS[chunk_idx]
    order = _orders(len(sizes), order_idx)
    p = int(np.prod(sizes))
    # ragged split: 13 is not divisible by any C > 1
    n = 13 if seed % 2 else 32
    x = _int_payload(p, n, seed)
    root = seed % p
    base = build_plan(coll, sizes, "sum", n * 4, order=order, root=root)
    chunked = dataclasses.replace(base, chunking=chunks)
    arg = None if coll == "BARRIER" else x
    got_base = np.asarray(lower_sim(base)(arg))
    got_chunked = np.asarray(lower_sim(chunked)(arg))
    np.testing.assert_array_equal(got_chunked, got_base)


@settings(max_examples=24, deadline=None)
@given(
    mesh_idx=st.integers(0, len(MESHES) - 1),
    inclusive=st.booleans(),
    chunk_idx=st.integers(1, len(CHUNKS) - 1),
    order_idx=st.integers(0, 5),
    seed=st.integers(0, 10_000),
)
def test_chunked_optimized_bitwise(
    mesh_idx, inclusive, chunk_idx, order_idx, seed
):
    """Chunking composed with the full pass pipeline (fused
    SCAN+TOTAL phases take the chunked_scan_total_schedule path): the
    chunked optimized plan equals both the unchunked optimized plan and
    the raw plan, bitwise, under jit."""
    sizes = MESHES[mesh_idx]
    chunks = CHUNKS[chunk_idx]
    order = _orders(len(sizes), order_idx)
    coll = "SCAN" if inclusive else "EXSCAN"
    p = int(np.prod(sizes))
    x = _int_payload(p, 24, seed)
    raw = build_plan(coll, sizes, "sum", 96, order=order)
    opt = optimize_plan(raw)
    opt_chunked = dataclasses.replace(opt, chunking=chunks)
    got_raw = np.asarray(jax.jit(lower_sim(raw))(x))
    got_opt = np.asarray(jax.jit(lower_sim(opt))(x))
    got_chunked = np.asarray(jax.jit(lower_sim(opt_chunked))(x))
    np.testing.assert_array_equal(got_opt, got_raw)
    np.testing.assert_array_equal(got_chunked, got_raw)


@settings(max_examples=16, deadline=None)
@given(
    mesh_idx=st.integers(0, 3),
    inclusive=st.booleans(),
    chunks=st.sampled_from([2, 4]),
    seed=st.integers(0, 10_000),
)
def test_chunked_ssd_bitwise(mesh_idx, inclusive, chunks, seed):
    """Non-commutative SSD (decay, state) recurrence stays bitwise under
    chunking — chunk boundaries must not reorder the combine tree."""
    sizes = [(2, 4), (4, 2), (2, 2, 2), (2, 8)][mesh_idx]
    p = int(np.prod(sizes))
    rng = np.random.default_rng(seed)
    a = jnp.asarray(
        rng.choice([0.5, 1.0, 2.0], size=(p, 4)).astype(np.float32)
    )
    b = jnp.asarray(rng.integers(-4, 5, size=(p, 4)).astype(np.float32))
    coll = "SCAN" if inclusive else "EXSCAN"
    base = build_plan(coll, sizes, SSD, 32)
    chunked = dataclasses.replace(base, chunking=chunks)
    ra, rb = lower_sim(base, SSD)((a, b))
    ca, cb = lower_sim(chunked, SSD)((a, b))
    np.testing.assert_array_equal(np.asarray(ca), np.asarray(ra))
    np.testing.assert_array_equal(np.asarray(cb), np.asarray(rb))


# ------------------------------------- C=1 wire- and cache-key stability


def test_c1_descriptor_encodes_to_pre_chunking_wire_form():
    """chunks=1 must be byte-invisible: same word count and same words as
    a descriptor built before the chunks field existed."""
    eng = OffloadEngine()
    desc = eng.make_descriptor(
        "SCAN", axes=(2, 4), payload_bytes=1024, op="sum", chunks=1
    )
    words = desc.encode()
    assert len(words) == _OPT_WORDS  # 16 — no 17th chunk word at C=1
    legacy = dataclasses.replace(desc, chunks=1).encode()
    np.testing.assert_array_equal(np.asarray(words), np.asarray(legacy))
    # decoding the 16-word form yields chunks=1, i.e. the same descriptor
    assert CollectiveDescriptor.decode(words) == desc


def test_chunked_descriptor_round_trips_17_words():
    eng = OffloadEngine()
    desc = eng.make_descriptor(
        "SCAN", axes=(2, 4), payload_bytes=1 << 20, op="sum", chunks=4
    )
    words = desc.encode()
    assert len(words) == _CHUNK_WORDS  # 17
    assert words[_CHUNK_WORDS - 1] == 4
    assert CollectiveDescriptor.decode(words) == desc


def test_chunks_require_planned_descriptor():
    eng = OffloadEngine()
    with pytest.raises(ValueError):
        eng.make_descriptor("SCAN", p=8, payload_bytes=64, chunks=2)
    with pytest.raises(ValueError):
        CollectiveDescriptor(coll_type=CollType.SCAN, comm_size=8, chunks=2)
    with pytest.raises(ValueError):
        CollectiveDescriptor(coll_type=CollType.SCAN, comm_size=8, chunks=0)


def test_c1_cache_key_stable_and_chunked_keys_distinct():
    """Cache-key regression: a chunks=1 descriptor and its 16-word wire
    decode land in the SAME compiled-schedule cache entry (C=1 compiles to
    the identical schedule as before this feature), while a chunked
    descriptor gets its own entry."""
    eng = OffloadEngine()
    x = _int_payload(8, 16, 3)
    d1 = eng.make_descriptor(
        "SCAN", axes=(2, 4), payload_bytes=64, op="sum", chunks=1
    )
    y1 = np.asarray(eng.offload(d1, x))
    assert (eng.telemetry.hits, eng.telemetry.misses) == (0, 1)
    # wire round-trip (16 words, no chunk word) must hit the same entry
    y2 = np.asarray(eng.offload(d1.encode(), x))
    assert (eng.telemetry.hits, eng.telemetry.misses) == (1, 1)
    np.testing.assert_array_equal(y2, y1)
    # a chunked sibling is a different compiled schedule (miss) ...
    d4 = dataclasses.replace(d1, chunks=4)
    y4 = np.asarray(eng.offload(d4, x))
    assert (eng.telemetry.hits, eng.telemetry.misses) == (1, 2)
    # ... but the same bits
    np.testing.assert_array_equal(y4, y1)
    # and its own 17-word wire form hits the chunked entry
    np.asarray(eng.offload(d4.encode(), x))
    assert (eng.telemetry.hits, eng.telemetry.misses) == (2, 2)


@pytest.mark.parametrize("coll", ["SCAN", "EXSCAN", "ALLREDUCE"])
def test_engine_chunked_dispatch_bitwise(coll):
    """End-to-end engine dispatch: explicit chunks=2 planned descriptor is
    bitwise-equal to the chunks=1 dispatch for pipelined and
    non-pipelined CollTypes alike."""
    eng = OffloadEngine()
    x = _int_payload(8, 32, 7)
    kw = dict(axes=(2, 4), payload_bytes=128, op="sum")
    y1 = np.asarray(
        eng.offload(eng.make_descriptor(coll, chunks=1, **kw), x)
    )
    y2 = np.asarray(
        eng.offload(eng.make_descriptor(coll, chunks=2, **kw), x)
    )
    np.testing.assert_array_equal(y2, y1)


# ------------------------------------------- chunk-selection pass + tuning


def test_select_chunking_payload_threshold():
    """The cost model keeps C=1 below the crossover and picks C>1 above
    it, only for plans with a pipelined (doubling-scan) phase."""
    plan = build_plan("SCAN", (2, 8), "sum", 1024)
    assert select_chunking(plan, 1024).chunking == 1
    big = select_chunking(plan, 4 << 20).chunking
    assert big > 1
    assert big in CHUNK_CANDIDATES
    # pure reduction: no pipelined phase, chunking stays 1 at any payload
    red = build_plan("ALLREDUCE", (2, 8), "sum", 4 << 20)
    assert select_chunking(red, 4 << 20).chunking == 1


def test_select_chunking_monotone_engagement():
    """Chunk counts never decrease as payload grows (the pipelined cost
    model is a sum of a C-decreasing and a C-increasing term)."""
    plan = build_plan("SCAN", (2, 2, 2), "sum", 1024)
    picks = [
        select_chunking(plan, b).chunking
        for b in (1 << 10, 1 << 14, 1 << 18, 1 << 22, 1 << 24)
    ]
    assert picks == sorted(picks)


def test_choose_schedule_prefers_measured_winner():
    """An active tuning table with a recorded schedule winner overrides
    the cost model, and make_descriptor(optimize='auto') inherits it."""
    coll, sizes, payload = "scan", (2, 4), 1024
    cache = TuningCache()
    # cost model alone would never chunk a 1KB payload ...
    assert choose_schedule(coll, sizes, payload)[1] == 1
    # ... but a measured table that saw (optimized, C=4) win rules
    cache.record_schedule(coll, sizes, False, 1, payload, 9e-4)
    cache.record_schedule(coll, sizes, True, 1, payload, 8e-4)
    cache.record_schedule(coll, sizes, True, 4, payload, 2e-4)
    assert cache.schedule_winner(coll, sizes, payload) == (True, 4)
    cache.activate()
    try:
        assert choose_schedule(coll, sizes, payload) == (True, 4)
        eng = OffloadEngine()
        desc = eng.make_descriptor(
            "SCAN", axes=sizes, payload_bytes=payload, op="sum"
        )
        assert (desc.optimized, desc.chunks) == (True, 4)
        x = _int_payload(8, 16, 11)
        raw = np.asarray(
            eng.offload(
                eng.make_descriptor(
                    "SCAN", axes=sizes, payload_bytes=payload, op="sum",
                    optimize=False, chunks=1,
                ),
                x,
            )
        )
        np.testing.assert_array_equal(np.asarray(eng.offload(desc, x)), raw)
    finally:
        set_active_tuning(None)


def test_schedule_winner_tie_break_prefers_unchunked():
    """Equal measurements: the winner is the simpler schedule (optimized
    first, then the smaller chunk count) so noise cannot flip C upward."""
    cache = TuningCache()
    cache.record_schedule("scan", (2, 4), True, 1, 1024, 5e-4)
    cache.record_schedule("scan", (2, 4), True, 8, 1024, 5e-4)
    assert cache.schedule_winner("scan", (2, 4), 1024) == (True, 1)


# ------------------------------------------------- algorithm-level helpers


def test_chunk_bounds_and_split_concat_round_trip():
    assert alg.chunk_bounds(13, 4) == [0, 3, 6, 9, 13]
    assert alg.chunk_bounds(8, 4) == [0, 2, 4, 6, 8]
    x = _int_payload(4, 13, 0)
    parts = alg.split_chunks(x, 4)
    assert len(parts) == 4
    np.testing.assert_array_equal(
        np.asarray(alg.concat_chunks(parts)), np.asarray(x)
    )


@pytest.mark.parametrize("chunks", [2, 4, 8])
@pytest.mark.parametrize("algo", sorted(alg.DOUBLING_ALGORITHMS))
def test_run_chunked_matches_unchunked(algo, chunks):
    """Direct algorithm-level pipeline: run_chunked over a SimBackend
    equals the plain doubling scan, bitwise, for both doubling variants."""
    p, n = 8, 24
    x = _int_payload(p, n, chunks)
    op = get_operator("sum")
    fn = alg.get_algorithm(algo)
    backend = alg.SimBackend(p)
    want = np.asarray(fn(backend, x, op))
    got = np.asarray(
        alg.run_chunked(lambda t: fn(backend, t, op), x, chunks)
    )
    np.testing.assert_array_equal(got, want)


def test_chunked_scan_schedule_requires_chunkable_payload():
    """A payload whose trailing axis can't be split (fewer elements than
    chunks) falls back to the unchunked path rather than failing."""
    p = 8
    x = _int_payload(p, 1, 5)  # last dim 1 < chunks
    op = get_operator("sum")
    backend = alg.SimBackend(p)
    want = np.asarray(alg.hillis_steele(backend, x, op))
    got = np.asarray(
        alg.run_chunked(
            lambda t: alg.hillis_steele(backend, t, op), x, 4
        )
    )
    np.testing.assert_array_equal(got, want)


# ------------------------------------------------- per-(round, chunk) spans


def test_traced_chunked_dispatch_labels_round_spans():
    """A traced chunked dispatch stays bitwise-identical to the untraced
    one and labels its pipelined round spans with (chunk, chunk_round)
    coordinates; the unchunked dispatch emits no chunk labels at all."""
    from repro.obs import tracing as obs_tracing

    eng = OffloadEngine()
    x = _int_payload(8, 32, 13)
    kw = dict(axes=(2, 4), payload_bytes=128, op="sum", optimize=True)
    d1 = eng.make_descriptor("scan", chunks=1, **kw)
    d2 = eng.make_descriptor("scan", chunks=2, **kw)
    want = np.asarray(eng.offload(d1, x))
    try:
        with obs_tracing.tracing() as tracer:
            got = np.asarray(eng.offload(d2, x))
        np.testing.assert_array_equal(got, want)
        rounds = [s for s in tracer.spans() if s.cat == "round"]
        assert rounds
        labelled = [s for s in rounds if "chunk" in s.args]
        assert labelled, "chunked dispatch emitted no chunk-labelled rounds"
        for s in labelled:
            assert 0 <= s.args["chunk"] < 2
            assert s.args["chunk_round"] >= 0
        with obs_tracing.tracing() as tracer:
            np.testing.assert_array_equal(
                np.asarray(eng.offload(d1, x)), want
            )
        assert not any(
            "chunk" in s.args
            for s in tracer.spans()
            if s.cat == "round"
        )
    finally:
        obs_tracing.set_tracer(None)
