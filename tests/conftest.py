import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"


def run_check_module(module: str, *args: str, timeout: int = 420) -> str:
    """Run a repro.testing.* module in a fresh subprocess (multi-device
    checks need xla_force_host_platform_device_count set before jax import,
    which the already-initialized test process can't do)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", module, *args],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
        cwd=str(REPO),
    )
    if proc.returncode != 0 or "ALL-OK" not in proc.stdout:
        raise AssertionError(
            f"{module} failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout[-4000:]}\n"
            f"--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def subprocess_runner():
    return run_check_module
