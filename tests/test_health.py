"""Health-stack tests: flight-recorder ring semantics and crash dumps,
multi-window burn-rate SLO alerting (injected clock), telemetry-snapshot
ingestion with counter-reset re-basing, per-link straggler attribution
(peer-relative flagging, report rising edges, span ingestion), the text
dashboard + HTTP endpoints, broker deadline-miss flight events, the
bounded step-straggler ring, and the health_check CI module."""

import json
import threading
import urllib.request

import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import dashboard as obs_dashboard
from repro.obs import events as obs_events
from repro.obs import health as obs_health
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.offload import OffloadEngine
from repro.runtime.straggler import StragglerDetector
from repro.service import DescriptorBroker

AXES = (2, 4)
P = 8
N = 16


@pytest.fixture(autouse=True)
def _clean_obs():
    obs_events.set_recorder(None)
    obs_events.set_auto_dump_path(None)
    obs_metrics.reset_registry()
    obs_tracing.set_tracer(None)
    yield
    obs_events.set_recorder(None)
    obs_events.set_auto_dump_path(None)
    obs_metrics.reset_registry()
    obs_tracing.set_tracer(None)


def _x(seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-5, 6, size=(P, N)).astype(np.float32))


# ------------------------------------------------------- flight recorder


def test_recorder_ring_bounds_and_counts():
    rec = obs_events.FlightRecorder(capacity=8)
    for i in range(20):
        rec.record("dispatch", i=i)
    assert len(rec) == 8
    events = rec.events()
    # ring keeps the newest events, seq keeps counting past eviction
    assert [e["i"] for e in events] == list(range(12, 20))
    assert events[-1]["seq"] == 20
    assert rec.counts() == {"dispatch": 20}
    snap = rec.snapshot("test")
    assert snap["recorded"] == 20 and snap["evicted"] == 12
    assert snap["capacity"] == 8


def test_recorder_filter_and_limit():
    rec = obs_events.FlightRecorder()
    rec.record("dispatch", coll="SCAN")
    rec.record("cache_miss", coll="SCAN")
    rec.record("dispatch", coll="EXSCAN")
    assert [e["coll"] for e in rec.events(kind="dispatch")] == [
        "SCAN", "EXSCAN"
    ]
    assert [e["kind"] for e in rec.events(limit=1)] == ["dispatch"]
    rec.clear()
    assert len(rec) == 0 and rec.counts() == {}


def test_recorder_dump_writes_valid_json(tmp_path):
    rec = obs_events.FlightRecorder()
    rec.record("remesh", old_axes=(2, 4), new_axes=(2, 2))
    out = tmp_path / "sub" / "flight.json"  # parent dir must be created
    rec.dump(out, reason="unit")
    data = json.loads(out.read_text())
    assert data["reason"] == "unit"
    assert data["events"][0]["kind"] == "remesh"
    # the successful dump itself is recorded
    assert rec.counts().get("dump") == 1


def test_recorder_dump_failure_never_raises(tmp_path):
    rec = obs_events.FlightRecorder()
    rec.record("recovery", error="boom")
    target = tmp_path / "file"
    target.write_text("")  # a *file* where a directory is needed
    snap = rec.dump(target / "flight.json", reason="crash")
    assert snap["events"][0]["kind"] == "recovery"
    dumps = rec.events(kind="dump")
    assert dumps and "error" in dumps[0]


def test_auto_dump_path_and_trigger(tmp_path):
    assert obs_events.auto_dump("noop") is None  # unconfigured: no-op
    target = tmp_path / "auto.json"
    obs_events.set_auto_dump_path(target)
    obs_events.record("recovery", error="x")
    assert obs_events.auto_dump("recovery") == target
    assert json.loads(target.read_text())["reason"] == "recovery"


def test_set_recorder_swaps_global():
    mine = obs_events.FlightRecorder()
    prev = obs_events.set_recorder(mine)
    try:
        obs_events.record("flush", requests=3)
        assert mine.counts() == {"flush": 1}
    finally:
        obs_events.set_recorder(prev)
    assert obs_events.get_recorder() is prev


# ------------------------------------------------------------------ SLOs


def test_slo_validation():
    with pytest.raises(ValueError):
        obs_health.SLO("bad", objective=1.0)
    with pytest.raises(ValueError):
        obs_health.SLO("bad", fast_window_s=600.0, slow_window_s=60.0)
    assert obs_health.SLO("ok", objective=0.99).error_budget == pytest.approx(
        0.01
    )


def _clocked_monitor(slo, **kw):
    now = {"t": 1000.0}
    mon = obs_health.HealthMonitor((slo,), clock=lambda: now["t"], **kw)
    return mon, now


def test_burn_rate_alert_needs_both_windows():
    """Errors only inside the fast window must not alert: the slow window
    is the page-on-a-single-bad-flush guard."""
    slo = obs_health.SLO(
        "deadline_miss", objective=0.99,
        fast_window_s=10.0, slow_window_s=100.0, min_events=1,
    )
    mon, now = _clocked_monitor(slo)
    # long healthy history, then a recent burst of misses
    for i in range(90):
        mon.observe("deadline_miss", key="t0", good=10.0, t=910.0 + i)
    mon.observe("deadline_miss", key="t0", bad=5.0, t=999.0)
    # fast window (990-1000): 5 bad / 15 -> burn 33; slow window: 5/905
    # -> burn 0.55 < 1 -> no alert
    assert mon.evaluate() == []
    # keep burning: push the slow window over budget too
    for i in range(10):
        mon.observe("deadline_miss", key="t0", bad=10.0, t=999.5)
    alerts = mon.evaluate()
    assert [(a.slo, a.key) for a in alerts] == [("deadline_miss", "t0")]
    assert alerts[0].burn_fast >= 1.0 and alerts[0].burn_slow >= 1.0


def test_alert_rising_edge_recorded_once():
    slo = obs_health.SLO(
        "deadline_miss", objective=0.9,
        fast_window_s=10.0, slow_window_s=10.0, min_events=1,
    )
    rec = obs_events.FlightRecorder()
    mon, now = _clocked_monitor(slo, recorder=rec)
    mon.observe("deadline_miss", key="a", bad=5.0, t=999.0)
    assert len(mon.evaluate()) == 1
    assert len(mon.evaluate()) == 1  # still firing...
    assert rec.counts().get("slo_alert") == 1  # ...recorded once
    # window expires -> alert clears -> next breach is a new rising edge
    now["t"] = 2000.0
    assert mon.evaluate() == []
    mon.observe("deadline_miss", key="a", bad=5.0, t=1999.0)
    assert len(mon.evaluate()) == 1
    assert rec.counts().get("slo_alert") == 2


def test_min_events_gates_sparse_series():
    slo = obs_health.SLO(
        "deadline_miss", objective=0.99,
        fast_window_s=10.0, slow_window_s=10.0, min_events=5,
    )
    mon, _ = _clocked_monitor(slo)
    mon.observe("deadline_miss", key="a", bad=1.0, t=999.0)
    assert mon.evaluate() == []  # 1 event < min_events: no data, no alert


def test_observe_unknown_slo_raises():
    mon, _ = _clocked_monitor(obs_health.SLO("deadline_miss"))
    with pytest.raises(KeyError):
        mon.observe("nope", bad=1.0)


def test_ingest_diffs_cumulative_engine_snapshots():
    slo = obs_health.SLO(
        "cache_hit", objective=0.5,
        fast_window_s=10.0, slow_window_s=10.0, min_events=1,
    )
    mon, now = _clocked_monitor(slo)
    mon.ingest(engine={"hits": 0, "misses": 2, "dispatches": 2})
    assert len(mon.evaluate()) == 1  # 0/2 hit rate burns the 50% budget
    # counters advance: 8 more hits, 0 more misses -> healthy increment
    now["t"] = 1005.0
    mon.ingest(engine={"hits": 8, "misses": 2, "dispatches": 10})
    # fast window now holds 8 good / 2 bad -> error rate 0.2 < 0.5
    assert mon.evaluate() == []
    # telemetry reset (counter goes backwards) re-bases instead of
    # producing a negative increment
    now["t"] = 1009.0
    mon.ingest(engine={"hits": 1, "misses": 0, "dispatches": 1})
    assert mon.evaluate() == []


def test_healthz_payload_shape():
    mon, _ = _clocked_monitor(obs_health.SLO("deadline_miss"))
    hz = mon.healthz()
    assert hz["status"] == "ok"
    assert hz["alerts"] == [] and hz["stragglers"] == []
    assert "deadline_miss" in hz["slos"]


# ------------------------------------------------- link straggler detector


def test_link_detector_flags_peer_relative():
    rec = obs_events.FlightRecorder()
    det = obs_health.LinkStragglerDetector(
        min_samples=2, report_after=3, threshold=2.0, recorder=rec
    )
    reported = []
    det.on_report(reported.append)
    verdict = {}
    for _ in range(6):
        det.observe(0, 0, 1, 100.0)
        det.observe(0, 1, 2, 110.0)
        verdict = det.observe(0, 2, 0, 900.0)
    assert verdict["flagged"] and verdict["report"]
    assert verdict["peer_us"] == pytest.approx(105.0)
    top = det.straggler()
    assert (top["axis"], top["src"], top["dst"]) == (0, 2, 0)
    assert len(det.reports()) == 1
    # report fired exactly once (rising edge), into callbacks + recorder
    assert len(reported) == 1
    assert rec.counts().get("straggler_link") == 1
    prom = obs_metrics.render_prometheus()
    assert "repro_link_straggler_reports_total" in prom


def test_link_detector_no_flag_without_same_axis_peer():
    """A lone link (or peers on another axis) has no baseline: never flag."""
    det = obs_health.LinkStragglerDetector(min_samples=1, report_after=1)
    for _ in range(5):
        v = det.observe(0, 0, 1, 5000.0)
        det.observe(1, 0, 1, 10.0)  # other axis: not a peer
    assert not v["flagged"] and det.reports() == []


def test_link_detector_uniform_slowness_flags_nothing():
    """A globally slow round moves every link: peer-relative stays quiet."""
    det = obs_health.LinkStragglerDetector(min_samples=2, report_after=2)
    for _ in range(6):
        for (a, s, d) in [(0, 0, 1), (0, 1, 2), (0, 2, 0)]:
            v = det.observe(a, s, d, 5000.0)
    assert not v["flagged"] and det.reports() == []


def test_link_detector_consecutive_resets_on_recovery():
    det = obs_health.LinkStragglerDetector(
        min_samples=1, report_after=3, threshold=2.0, alpha=1.0
    )
    for _ in range(3):
        det.observe(0, 0, 1, 100.0)
        det.observe(0, 1, 0, 100.0)
    det.observe(0, 0, 1, 900.0)   # flag 1
    det.observe(0, 0, 1, 900.0)   # flag 2
    det.observe(0, 0, 1, 100.0)   # recovered: consecutive resets
    det.observe(0, 0, 1, 900.0)   # flag 1 again — never hits 3
    assert det.reports() == []


def test_link_detector_observe_spans():
    det = obs_health.LinkStragglerDetector(min_samples=1, report_after=1)
    tracer = obs_tracing.Tracer()
    with tracer.span("plan.round:0", "round"):
        with tracer.span("plan.link:L0:0->1", "link", axis=0, src=0, dst=1):
            pass
    n = det.observe_spans(tracer.spans())
    assert n == 1  # round span skipped, link span consumed
    assert det.summary()[0]["samples"] == 1


def test_link_injector_table():
    inj = obs_health.LinkDelayInjector({(1, 0, 1): 0.25})
    assert inj.delay(1, 0, 1) == 0.25
    assert inj.delay(0, 0, 1) == 0.0
    inj.set_delay(0, 1, 0, 0.5)
    assert inj.delay(0, 1, 0) == 0.5


def test_link_probe_dispatch_bitwise_and_spans():
    """The per-link probe decomposition must be bitwise-invisible and emit
    link spans parented to round spans."""
    eng = OffloadEngine()
    desc = eng.make_descriptor(
        "scan", axes=AXES, payload_bytes=N * 4, op="sum", optimize=True
    )
    x = _x()
    baseline = np.asarray(eng.offload(desc, x))
    det = obs_health.LinkStragglerDetector()
    tracer = obs_tracing.Tracer(link_probe=True, link_detector=det)
    with obs_tracing.tracing(tracer):
        probed = np.asarray(eng.offload(desc, x))
    assert np.array_equal(probed, baseline)
    spans = tracer.spans()
    links = [s for s in spans if s.cat == "link"]
    rounds = {s.span_id for s in spans if s.cat == "round"}
    assert links and all(s.parent_id in rounds for s in links)
    assert all(
        {"axis", "src", "dst", "round"} <= set(s.args) for s in links
    )
    assert sum(r["samples"] for r in det.summary()) == len(links)


# ------------------------------------------------------------- dashboard


def test_render_dashboard_sections():
    eng = OffloadEngine()
    desc = eng.make_descriptor(
        "scan", axes=AXES, payload_bytes=N * 4, op="sum", optimize=True
    )
    eng.offload(desc, _x())
    mon = obs_health.HealthMonitor()
    text = obs_dashboard.render_dashboard(engine=eng, monitor=mon)
    assert "engine" in text and "dispatches 1" in text
    assert "health: OK" in text
    assert "flight recorder" in text
    assert "dispatch" in text  # the dispatch event tail line


def test_http_endpoints_serve_health_metrics_events():
    rec = obs_events.get_recorder()
    rec.record("dispatch", coll="SCAN", cache="hit")
    mon, _ = _clocked_monitor(
        obs_health.SLO(
            "deadline_miss", objective=0.9,
            fast_window_s=10.0, slow_window_s=10.0,
        )
    )
    obs_metrics.get_registry().counter("repro_probe_total", "probe").inc()

    def get(path):
        req = urllib.request.Request(url + path)
        try:
            with urllib.request.urlopen(req, timeout=10) as r:
                return r.status, r.read().decode()
        except urllib.error.HTTPError as e:
            return e.code, e.read().decode()

    with obs_dashboard.start_http_server(monitor=mon, recorder=rec) as srv:
        url = srv.url
        status, body = get("/healthz")
        assert status == 200 and json.loads(body)["status"] == "ok"
        status, body = get("/metrics")
        assert status == 200 and "repro_probe_total" in body
        status, body = get("/events?kind=dispatch&limit=5")
        payload = json.loads(body)
        assert status == 200
        assert payload["events"][0]["coll"] == "SCAN"
        status, body = get("/dashboard")
        assert status == 200 and "flight recorder" in body
        status, _ = get("/nope")
        assert status == 404
        # an SLO breach flips /healthz to 503 for load-balancer probes
        mon.observe("deadline_miss", key="a", bad=5.0, t=999.0)
        status, body = get("/healthz")
        assert status == 503 and json.loads(body)["status"] == "alert"


# ----------------------------------------------- broker deadline events


def test_broker_deadline_miss_flight_event_and_counter():
    rec = obs_events.FlightRecorder()
    prev = obs_events.set_recorder(rec)
    try:
        broker = DescriptorBroker(OffloadEngine()).start()
        try:
            client = broker.client("slowpoke")
            desc = broker.make_descriptor(
                "SCAN", p=P, payload_bytes=N * 4, op="sum"
            )
            client.submit(desc, _x(), deadline_s=1e-6).result(timeout=60.0)
        finally:
            broker.stop()
    finally:
        obs_events.set_recorder(prev)
    misses = rec.events(kind="deadline_miss")
    assert len(misses) == 1
    m = misses[0]
    assert m["tenant"] == "slowpoke" and m["group"] == 1
    assert m["queue_wait_s"] >= 0.0 and m["overrun_s"] > 0.0
    assert rec.counts().get("flush", 0) >= 1
    prom = obs_metrics.render_prometheus()
    assert 'repro_service_deadline_misses_total{tenant="slowpoke"} 1' in prom


# ------------------------------------------- step straggler ring + events


def test_step_straggler_events_bounded_and_recorded():
    rec = obs_events.FlightRecorder()
    prev = obs_events.set_recorder(rec)
    try:
        det = StragglerDetector(
            threshold=2.0, evict_after=3, warmup=1, max_events=4
        )
        for step in range(5):
            verdict = det.observe(step, 0.1)
        assert set(verdict) == {"flagged", "evict", "ewma"}  # contract
        for step in range(5, 15):
            verdict = det.observe(step, 10.0)  # every step flags
        assert verdict["flagged"] and verdict["evict"]
        assert len(det.events) == 4  # bounded ring, newest kept
        assert det.events[-1]["step"] == 14
    finally:
        obs_events.set_recorder(prev)
    assert rec.counts().get("straggler_flag", 0) == 10
    assert rec.counts().get("straggler_evict", 0) == 1  # rising edge only


# ------------------------------------------------------------- CI module


def test_health_check_module(subprocess_runner):
    out = subprocess_runner("repro.testing.health_check", "2", "2")
    assert (
        "health_check_summary,bitwise_equal,1,straggler_axis,1,"
        "straggler_src,0,straggler_dst,1,attribution_ok,1,slo_alert,1,"
        "dump_valid,1" in out
    )
