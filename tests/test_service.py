"""Service-subsystem tests: broker coalescing (bitwise vs direct dispatch),
backpressure and admission control, per-tenant telemetry, the tuning-table
registry (merge conflict policy, fingerprint keying, persistence), and the
broker inheriting another worker's split winner."""

import threading

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import CollType
from repro.core.selector import set_active_tuning
from repro.offload import OffloadEngine, TuningCache
from repro.service import (
    AdmissionError,
    BrokerStopped,
    DescriptorBroker,
    FileTuningRegistry,
    LatencyHistogram,
    QueueFullError,
    ServiceTelemetry,
    TuningRegistry,
)

P = 8
N = 16


@pytest.fixture(autouse=True)
def _no_active_tuning():
    set_active_tuning(None)
    yield
    set_active_tuning(None)


def _payloads(k, seed=0):
    rng = np.random.default_rng(seed)
    return [
        jnp.asarray(rng.normal(size=(P, N)).astype(np.float32))
        for _ in range(k)
    ]


def _scan_desc(broker):
    return broker.make_descriptor("SCAN", p=P, payload_bytes=N * 4, op="sum")


# ------------------------------------------------------------- coalescing


@pytest.mark.parametrize("coll", [c.name for c in CollType])
def test_coalesced_dispatch_bitwise_equals_direct(coll):
    """Four tenants' fused dispatch == four direct engine dispatches, per
    CollType, bit for bit."""
    broker = DescriptorBroker(OffloadEngine())
    direct = OffloadEngine()
    desc = broker.make_descriptor(coll, p=P, payload_bytes=N * 4, op="sum")
    xs = _payloads(4)
    is_barrier = coll == "BARRIER"
    clients = [broker.client() for _ in range(4)]
    tickets = [
        c.submit(desc.encode(), None if is_barrier else x)
        for c, x in zip(clients, xs)
    ]
    assert broker.drain() == 4
    for t, x in zip(tickets, xs):
        got = np.asarray(t.result(5))
        want = np.asarray(direct.offload(desc, None if is_barrier else x))
        np.testing.assert_array_equal(got, want)
    # four requests, one engine dispatch
    assert broker.telemetry.coalesce_factor == 4.0
    assert broker.engine.telemetry.dispatches == 1


def test_coalesce_groups_split_by_descriptor_and_shape():
    """Different descriptors (or payload shapes) never fuse."""
    broker = DescriptorBroker(OffloadEngine())
    scan = _scan_desc(broker)
    allred = broker.make_descriptor(
        "ALLREDUCE", p=P, payload_bytes=N * 4, op="sum"
    )
    xs = _payloads(3)
    wide = jnp.concatenate([xs[2], xs[2]], axis=1)  # different leaf shape
    a = broker.client("a")
    t1 = a.submit(scan.encode(), xs[0])
    t2 = broker.client("b").submit(allred.encode(), xs[1])
    t3 = broker.client("c").submit(scan.encode(), wide)
    assert broker.drain() == 3
    for t in (t1, t2, t3):
        t.result(5)
    assert broker.engine.telemetry.dispatches == 3
    assert broker.telemetry.coalesce_factor == 1.0


def test_pytree_payloads_coalesce():
    """Tuple-pytree payloads stack leafwise and unstack bitwise."""
    broker = DescriptorBroker(OffloadEngine())
    direct = OffloadEngine()
    desc = broker.make_descriptor(
        "SCAN", p=P, payload_bytes=2 * N * 4, op="ssd"
    )
    rng = np.random.default_rng(3)

    def pair(seed):
        r = np.random.default_rng(seed)
        return (
            jnp.asarray(r.uniform(0.5, 1.0, (P, N)).astype(np.float32)),
            jnp.asarray(r.normal(size=(P, N)).astype(np.float32)),
        )

    pairs = [pair(s) for s in range(3)]
    tickets = [
        broker.client().submit(desc.encode(), pr) for pr in pairs
    ]
    broker.drain()
    assert broker.engine.telemetry.dispatches == 1
    for t, pr in zip(tickets, pairs):
        got_a, got_b = t.result(5)
        want_a, want_b = direct.offload(desc, pr)
        np.testing.assert_array_equal(np.asarray(got_a), np.asarray(want_a))
        np.testing.assert_array_equal(np.asarray(got_b), np.asarray(want_b))


def test_threaded_clients_with_deadline_flush():
    """Started broker: concurrent submits complete within the flush window;
    a lone request is not starved."""
    with DescriptorBroker(OffloadEngine(), flush_interval_s=0.02) as broker:
        desc = _scan_desc(broker)
        xs = _payloads(4)
        direct = OffloadEngine()
        clients = [broker.client() for _ in range(4)]
        barrier = threading.Barrier(4)
        results = {}

        def work(i):
            barrier.wait()
            results[i] = clients[i].offload(desc.encode(), xs[i], timeout=30)

        threads = [
            threading.Thread(target=work, args=(i,)) for i in range(4)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for i in range(4):
            np.testing.assert_array_equal(
                np.asarray(results[i]),
                np.asarray(direct.offload(desc, xs[i])),
            )
        # lone follow-up request: the deadline flush dispatches it alone
        lone = clients[0].offload(desc.encode(), xs[0], timeout=30)
        np.testing.assert_array_equal(
            np.asarray(lone), np.asarray(direct.offload(desc, xs[0]))
        )
    assert not broker.running


def test_pow2_padding_bounds_fused_shapes_and_stays_bitwise():
    """Groups of 3 and 4 share one fused (p, 4, n) shape; padding columns
    never leak into real tenants' results."""
    broker = DescriptorBroker(OffloadEngine())
    direct = OffloadEngine()
    desc = _scan_desc(broker)
    for k in (3, 4):
        xs = _payloads(k, seed=k)
        tickets = [broker.client().submit(desc.encode(), x) for x in xs]
        broker.drain()
        for t, x in zip(tickets, xs):
            np.testing.assert_array_equal(
                np.asarray(t.result(5)), np.asarray(direct.offload(desc, x))
            )
    # one descriptor-level schedule serves both group sizes, and padding
    # keeps the fused leaf shape identical across them
    assert broker.engine.telemetry.compiles == 1
    assert broker.telemetry.fused_dispatches == 2


def test_max_coalesce_chunks_groups():
    broker = DescriptorBroker(OffloadEngine(), max_coalesce=2)
    desc = _scan_desc(broker)
    xs = _payloads(5)
    tickets = [broker.client().submit(desc.encode(), x) for x in xs]
    broker.drain()
    for t in tickets:
        t.result(5)
    # 5 requests at max_coalesce=2 -> 3 dispatches (2+2+1)
    assert broker.engine.telemetry.dispatches == 3


# ------------------------------------------- backpressure + admission


def test_tenant_queue_bound_rejects_without_corrupting_others():
    broker = DescriptorBroker(OffloadEngine())
    desc = _scan_desc(broker)
    xs = _payloads(6)
    small = broker.client("small", max_queue_depth=2)
    other = broker.client("other")
    t_other = other.submit(desc.encode(), xs[0])
    small.submit(desc.encode(), xs[1])
    small.submit(desc.encode(), xs[2])
    with pytest.raises(QueueFullError):
        small.submit(desc.encode(), xs[3])
    broker.drain()
    direct = OffloadEngine()
    np.testing.assert_array_equal(
        np.asarray(t_other.result(5)),
        np.asarray(direct.offload(desc, xs[0])),
    )
    snap = broker.telemetry.snapshot()
    assert snap["tenants"]["small"]["rejected"] == 1
    assert snap["tenants"]["small"]["completed"] == 2
    assert snap["tenants"]["other"]["rejected"] == 0
    assert snap["tenants"]["other"]["completed"] == 1


def test_blocking_submit_times_out():
    broker = DescriptorBroker(OffloadEngine())
    desc = _scan_desc(broker)
    xs = _payloads(2)
    c = broker.client("blocky", max_queue_depth=1, block=True)
    c.submit(desc.encode(), xs[0])
    with pytest.raises(QueueFullError):
        c.submit(desc.encode(), xs[1], timeout=0.05)
    broker.drain()


def test_admission_control_caps_tenants_and_duplicate_names():
    broker = DescriptorBroker(OffloadEngine(), max_tenants=2)
    broker.client("a")
    b = broker.client("b")
    with pytest.raises(AdmissionError):
        broker.client("c")
    b.close()
    broker.client("c")  # freed slot is admissible again
    with pytest.raises(AdmissionError):
        broker.client("a")  # duplicate stream name


def test_stopped_broker_rejects_submissions():
    broker = DescriptorBroker(OffloadEngine())
    c = broker.client("a")
    broker.start()
    broker.stop()
    with pytest.raises(BrokerStopped):
        c.submit(_scan_desc(broker).encode(), _payloads(1)[0])


def test_stop_without_drain_accounts_dropped_requests():
    """Requests failed at shutdown still settle the per-tenant accounting:
    queue_depth returns to zero and submitted == completed + errors."""
    broker = DescriptorBroker(OffloadEngine())
    desc = _scan_desc(broker)
    c = broker.client("t0")
    tickets = [c.submit(desc.encode(), x) for x in _payloads(2)]
    broker.stop(drain=False)
    for t in tickets:
        with pytest.raises(BrokerStopped):
            t.result(5)
    snap = broker.telemetry.snapshot()["tenants"]["t0"]
    assert snap["queue_depth"] == 0
    assert snap["submitted"] == snap["completed"] + snap["errors"] == 2


def test_dispatch_error_reported_through_tickets_only():
    """A bad request fails its own group's tickets; the engine error counter
    moves; other tenants' results are unaffected."""
    broker = DescriptorBroker(OffloadEngine())
    desc = _scan_desc(broker)
    xs = _payloads(2)
    good = broker.client("good").submit(desc.encode(), xs[0])
    # wrong leading axis: sim payload validation fails at dispatch time
    bad = broker.client("bad").submit(
        desc.encode(), jnp.zeros((P // 2, N), jnp.float32)
    )
    broker.drain()
    np.testing.assert_array_equal(
        np.asarray(good.result(5)),
        np.asarray(OffloadEngine().offload(desc, xs[0])),
    )
    with pytest.raises(ValueError):
        bad.result(5)
    snap = broker.telemetry.snapshot()
    assert snap["tenants"]["bad"]["errors"] == 1
    assert snap["tenants"]["good"]["completed"] == 1


# --------------------------------------------------------------- telemetry


def test_latency_histogram_percentiles():
    h = LatencyHistogram()
    for us in (60, 60, 60, 300, 9000):
        h.record(us / 1e6)
    snap = h.snapshot()
    assert snap["count"] == 5
    assert snap["p50_us"] == 100.0     # bucket upper edge containing 60us
    # the 9ms sample lands in the (5ms, 10ms] bucket, but percentiles are
    # clamped to the observed range: report the 9ms max, not the 10ms edge
    assert snap["p99_us"] == pytest.approx(9000.0)
    assert snap["max_us"] == pytest.approx(9000.0)
    assert snap["min_us"] == pytest.approx(60.0)
    assert h.percentile_us(0.0) == pytest.approx(60.0)  # q=0 -> observed min
    with pytest.raises(ValueError):
        h.percentile_us(1.5)


def test_service_telemetry_snapshot_layers_engine():
    eng = OffloadEngine()
    tel = ServiceTelemetry(eng.telemetry)
    tel.record_submit("t0")
    tel.record_complete("t0", 0.001)
    tel.record_flush(3, 1, deadline=True)
    snap = tel.snapshot()
    assert snap["coalesce_factor"] == 3.0
    assert snap["deadline_flushes"] == 1
    assert snap["tenants"]["t0"]["queue_depth"] == 0
    assert "cache_clears" in snap["engine"]


def test_deadline_missed_counter():
    broker = DescriptorBroker(OffloadEngine())
    desc = _scan_desc(broker)
    c = broker.client("late")
    t = c.submit(desc.encode(), _payloads(1)[0], deadline_s=0.0)
    broker.drain()
    t.result(5)  # completes fine; the deadline miss is telemetry, not an error
    assert broker.telemetry.snapshot()["tenants"]["late"]["deadline_missed"] == 1


# ------------------------------------------------- tuning-table registry


def _disjoint_tables():
    """Two same-fingerprint tables with disjoint measurements; B holds the
    faster split for the (2, 2) mesh that A never measured."""
    a, b = TuningCache(), TuningCache()
    a.record("scan", "sklansky", 4, 1024, 9e-3)
    a.record_split("scan", (2, 2), (0, 1), 1024, 5e-3)
    b.record("scan", "hillis_steele", 4, 1024, 2e-3)
    b.record_split("scan", (2, 2), (1, 0), 1024, 1e-3)
    return a, b


def test_merge_same_key_prefers_lower_cost():
    a, b = TuningCache(), TuningCache()
    a.record("scan", "sklansky", 4, 1024, 9e-3)
    b.record("scan", "sklansky", 4, 1024, 2e-3)   # same key, faster sample
    b.record("scan", "hillis_steele", 4, 1024, 5e-3)
    a.merge(b)
    kept = {
        (m.coll, m.algo, m.p, m.payload_bytes): m.seconds
        for m in a.measurements
    }
    assert kept[("scan", "sklansky", 4, 1024)] == 2e-3
    assert a.winners[("scan", 4, 1024)] == "sklansky"
    # splits follow the same policy
    a.record_split("scan", (2, 2), (0, 1), 1024, 5e-3)
    c = TuningCache()
    c.record_split("scan", (2, 2), (0, 1), 1024, 1e-3)
    c.record_split("scan", (2, 2), (1, 0), 1024, 3e-3)
    a.merge(c)
    assert a.split_winners[("scan", (2, 2), 1024)] == (0, 1)


def test_merge_mismatched_fingerprint_raises():
    a = TuningCache()
    other = TuningCache(backend="tpu:v9:riscv")
    with pytest.raises(ValueError, match="backend"):
        a.merge(other)
    with pytest.raises(ValueError, match="backend"):
        other.merge(a)


def test_merged_table_load_compatible_round_trips(tmp_path):
    a, b = _disjoint_tables()
    a.merge(b)
    path = a.save(tmp_path / "merged.json")
    loaded = TuningCache.load_compatible(path)
    assert loaded is not None
    assert loaded.winners == a.winners
    assert loaded.split_winner("scan", (2, 2), 1024) == (1, 0)


def test_registry_merges_disjoint_tables_and_keys_by_fingerprint():
    a, b = _disjoint_tables()
    foreign = TuningCache(backend="tpu:v9:riscv")
    foreign.record("scan", "sklansky", 4, 1024, 1e-9)
    reg = TuningRegistry()
    reg.publish(a)
    reg.publish(foreign)   # different fingerprint: separate entry, no raise
    merged = reg.publish(b)
    assert merged.split_winner("scan", (2, 2), 1024) == (1, 0)
    assert merged.winners[("scan", 4, 1024)] == "hillis_steele"
    assert reg.fetch(backend="tpu:v9:riscv").winners[
        ("scan", 4, 1024)
    ] == "sklansky"
    assert len(reg.backends()) == 2
    assert reg.fetch(backend="never:seen:this") is None


def test_file_registry_persists_across_instances(tmp_path):
    a, b = _disjoint_tables()
    FileTuningRegistry(tmp_path).publish(a)
    FileTuningRegistry(tmp_path).publish(b)    # fresh "process"
    merged = FileTuningRegistry(tmp_path).fetch()
    assert merged is not None
    assert merged.split_winner("scan", (2, 2), 1024) == (1, 0)
    assert merged.winners[("scan", 4, 1024)] == "hillis_steele"
    assert FileTuningRegistry(tmp_path).backends() == [a.backend]


def test_broker_planner_inherits_other_workers_split_winner(tmp_path):
    """The acceptance demo: worker A publishes its table, worker B publishes
    a *disjoint* one holding the (2, 2) split winner; a broker built over
    the registry plans split="auto" with B's winner — which A (and the
    static cost model) never measured."""
    a, b = _disjoint_tables()
    reg = FileTuningRegistry(tmp_path)
    reg.publish(a)
    reg.publish(b)
    broker = DescriptorBroker(OffloadEngine(), registry=reg)
    assert broker.tuning_table is not None
    desc = broker.make_descriptor(
        "SCAN", axes=(2, 2), payload_bytes=1024, op="sum", split="auto"
    )
    assert desc.split == (1, 0)   # contributed by table b, not a
    # and the descriptor dispatches end-to-end under that split
    x = jnp.asarray(
        np.random.default_rng(0).normal(size=(4, 256)).astype(np.float32)
    )
    ticket = broker.client().submit(desc.encode(), x)
    broker.drain()
    got = np.asarray(ticket.result(5))
    want = np.asarray(np.cumsum(np.asarray(x), axis=0).astype(np.float32))
    np.testing.assert_allclose(got, want, atol=1e-4)
