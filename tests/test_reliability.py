"""Reliability-stack tests: seeded message-level chaos, payload/wire
integrity checksums, retry/backoff with deadlines, circuit breaking with
graceful degradation, broker group-bisection quarantine, and the
recovery-loop filtering that keeps dispatch faults from triggering a
remesh. The end-to-end contract (all five CollTypes bitwise through
chaos on a real mesh) lives in repro.testing.chaos_check, invoked via
the subprocess runner at the bottom."""

import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import CollType
from repro.core.packet import (
    CollectiveDescriptor,
    IntegrityError,
    WireDType,
    decode_checked,
    encode_checked,
    wire_checksum,
)
from repro.offload import OffloadEngine
from repro.offload.reliability import (
    DEGRADABLE_ERRORS,
    CircuitBreaker,
    CircuitOpenError,
    ReliabilityPolicy,
    ReliableDispatcher,
    RetryExhaustedError,
    RetryPolicy,
    _reset_full_coverage,
    payload_checksum,
    verify_payload,
)
from repro.runtime.chaos import (
    ChaosInjector,
    RateSchedule,
    TransportError,
    get_injector,
)
from repro.runtime.fault import FailureInjector, SimulatedFailure, is_recoverable
from repro.service import BrokerStopped, DescriptorBroker
from repro.service.broker import DEFAULT_RESULT_TIMEOUT_S

P = 8
N = 64
SEED = 1234


def _desc(engine_or_broker, coll="SCAN"):
    # multi-axis: chaos scopes only intercept the planned (multi-round)
    # sim path, where individual messages exist to be failed
    return engine_or_broker.make_descriptor(
        coll, axes=(2, 4), payload_bytes=N * 4, op="sum"
    )


def _payload(i=0):
    return jnp.arange(P * N, dtype=jnp.int32).reshape(P, N) + i


# ----------------------------------------------------------- chaos injector


def test_chaos_decisions_are_deterministic_per_seed():
    kw = dict(drop=0.3, corrupt=0.3, duplicate=0.2, reorder=0.2, delay=0.1)
    a, b = ChaosInjector(SEED, **kw), ChaosInjector(SEED, **kw)
    seq_a = [a.decide(0, s, (s + 1) % P) for s in range(200)]
    seq_b = [b.decide(0, s, (s + 1) % P) for s in range(200)]
    assert seq_a == seq_b
    assert a.faults_injected() == b.faults_injected() > 0
    c = ChaosInjector(SEED + 1, **kw)
    seq_c = [c.decide(0, s, (s + 1) % P) for s in range(200)]
    assert seq_c != seq_a


def test_chaos_counter_advance_changes_decisions():
    """A retried message draws a fresh verdict: decisions key on the
    global message counter, so identical links eventually diverge."""
    inj = ChaosInjector(SEED, drop=0.5)
    decisions = [inj.decide(0, 0, 1).drop for _ in range(64)]
    assert any(decisions) and not all(decisions)


def test_rate_schedules():
    burst = RateSchedule.burst(1.0, until=10)
    assert burst(9) == 1.0 and burst(10) == 0.0
    steps = RateSchedule.steps([(100, 0.2), (200, 0.8)])
    assert steps(50) == 0.2 and steps(150) == 0.8 and steps(250) == 0.0
    inj = ChaosInjector(SEED, drop=RateSchedule.burst(1.0, until=5))
    early = [inj.decide(0, 0, 1).drop for _ in range(5)]
    late = [inj.decide(0, 0, 1).drop for _ in range(20)]
    assert all(early) and not any(late)


def test_chaos_scope_installs_and_restores():
    assert get_injector() is None
    inj = ChaosInjector(SEED, drop=0.1)
    with inj.scope() as active:
        assert active is inj and get_injector() is inj
    assert get_injector() is None


def test_link_filter_restricts_faults():
    inj = ChaosInjector(SEED, drop=1.0, links=[(0, 0, 1)])
    assert inj.decide(0, 0, 1).drop
    assert not inj.decide(0, 2, 3).any


# ------------------------------------------- chaos + retries, end to end


def test_dispatch_bitwise_through_chaos_via_retries():
    eng = OffloadEngine()
    desc = _desc(eng)
    ref = np.asarray(eng.offload(desc, _payload()))
    dispatcher = ReliableDispatcher(
        eng,
        retry=RetryPolicy(max_attempts=40, backoff_s=1e-5, max_backoff_s=1e-4),
    )
    inj = ChaosInjector(SEED, drop=0.05, corrupt=0.05)
    with inj.scope():
        out = np.asarray(dispatcher.offload(desc, _payload()))
    assert np.array_equal(out, ref)
    assert inj.faults_injected() > 0
    assert dispatcher.counts["retries"] > 0


# ------------------------------------------------------------ retry policy


def test_retry_backoff_is_exponential_and_capped():
    rp = RetryPolicy(backoff_s=0.01, multiplier=2.0, max_backoff_s=0.05)
    assert [rp.backoff(a) for a in range(4)] == [0.01, 0.02, 0.04, 0.05]


def test_retry_exhaustion_carries_last_error_and_attempts():
    rp = RetryPolicy(max_attempts=3, backoff_s=0.0)
    calls = []

    def fn():
        calls.append(1)
        raise TransportError(f"boom {len(calls)}")

    with pytest.raises(RetryExhaustedError) as ei:
        rp.run(fn, sleep=lambda s: None)
    assert len(calls) == 3 and ei.value.attempts == 3
    assert isinstance(ei.value.last_error, TransportError)
    assert "boom 3" in str(ei.value.last_error)


def test_retry_succeeds_midway_and_reports_on_retry():
    rp = RetryPolicy(max_attempts=5, backoff_s=0.0)
    seen = []
    state = {"n": 0}

    def fn():
        state["n"] += 1
        if state["n"] < 3:
            raise TransportError("flaky")
        return "ok"

    out = rp.run(fn, sleep=lambda s: None,
                 on_retry=lambda n, e: seen.append(n))
    assert out == "ok" and seen == [0, 1]


def test_retry_never_sleeps_past_deadline():
    rp = RetryPolicy(max_attempts=10, backoff_s=1.0, max_backoff_s=1.0)
    clk = {"t": 100.0}
    slept = []

    def fn():
        raise TransportError("always")

    with pytest.raises(RetryExhaustedError) as ei:
        rp.run(
            fn,
            deadline=100.5,  # first 1s backoff would cross it
            clock=lambda: clk["t"],
            sleep=lambda s: slept.append(s),
        )
    assert slept == [] and ei.value.attempts == 1
    assert "deadline" in str(ei.value)


def test_retry_non_retryable_propagates_immediately():
    rp = RetryPolicy(max_attempts=5, backoff_s=0.0)
    calls = []

    def fn():
        calls.append(1)
        raise ValueError("caller bug")

    with pytest.raises(ValueError):
        rp.run(fn, sleep=lambda s: None)
    assert len(calls) == 1


# --------------------------------------------------------- circuit breaker


def test_breaker_trips_half_opens_and_recovers():
    clk = {"t": 0.0}
    br = CircuitBreaker(failure_threshold=3, cooldown_s=5.0,
                        clock=lambda: clk["t"])
    key = ("default", "scan")
    for _ in range(3):
        assert br.allow(key)
        br.record_failure(key)
    assert br.state(key) == "open" and not br.allow(key)
    clk["t"] = 6.0  # past cooldown: exactly one half-open probe admitted
    assert br.allow(key)
    assert br.state(key) == "half_open"
    br.record_success(key)
    assert br.state(key) == "closed" and br.allow(key)


def test_breaker_reopens_on_failed_probe():
    clk = {"t": 0.0}
    br = CircuitBreaker(failure_threshold=2, cooldown_s=1.0,
                        clock=lambda: clk["t"])
    key = ("pallas", "scan")
    br.record_failure(key)
    br.record_failure(key)
    clk["t"] = 2.0
    assert br.allow(key)  # probe
    br.record_failure(key)
    assert br.state(key) == "open" and not br.allow(key)
    assert key in br.open_keys()


# ------------------------------------------------------ degradation ladder


def test_strategies_ladder_strongest_first():
    eng = OffloadEngine()
    desc = eng.make_descriptor(
        "scan", axes=(2, 4), payload_bytes=N * 4, op="sum", optimize=True
    )
    chain = ReliableDispatcher.strategies(desc)
    labels = [label for label, _ in chain]
    assert labels[0] != "reference" and labels[-1] == "reference"
    assert chain[-1][1] is None
    # the raw rung strips optimization and chunking
    raw = dict(chain).get("raw")
    if raw is not None:
        assert not raw.optimized and raw.chunks == 1
    assert ReliableDispatcher.strategies(desc, degrade=False) == [
        (desc.backend or "default", desc)
    ]


def test_dispatcher_degrades_to_reference_under_total_loss():
    eng = OffloadEngine()
    desc = _desc(eng)
    ref = np.asarray(eng.offload(desc, _payload()))
    clk = {"t": 0.0}
    br = CircuitBreaker(failure_threshold=3, cooldown_s=5.0,
                        clock=lambda: clk["t"])
    dispatcher = ReliableDispatcher(
        eng,
        retry=RetryPolicy(max_attempts=2, backoff_s=0.0),
        breaker=br,
        clock=lambda: clk["t"],
        sleep=lambda s: None,
    )
    with ChaosInjector(SEED, drop=1.0).scope():
        for _ in range(4):
            out = np.asarray(dispatcher.offload(desc, _payload()))
            assert np.array_equal(out, ref)
    assert dispatcher.counts["reference_dispatches"] == 4
    assert dispatcher.counts["degrades"] >= 3
    assert br.state(("default", "scan")) == "open"
    # chaos lifted + cooldown elapsed: the half-open probe closes it
    clk["t"] = 10.0
    out = np.asarray(dispatcher.offload(desc, _payload()))
    assert np.array_equal(out, ref)
    assert br.state(("default", "scan")) == "closed"


def test_degradable_errors_do_not_mask_caller_bugs():
    assert TransportError in DEGRADABLE_ERRORS
    assert ValueError not in DEGRADABLE_ERRORS
    assert TypeError not in DEGRADABLE_ERRORS


def test_all_stages_open_raises_circuit_open():
    eng = OffloadEngine()
    desc = _desc(eng)
    clk = {"t": 0.0}
    br = CircuitBreaker(failure_threshold=1, cooldown_s=1e9,
                        clock=lambda: clk["t"])
    dispatcher = ReliableDispatcher(
        eng, retry=RetryPolicy(max_attempts=1, backoff_s=0.0), breaker=br,
        clock=lambda: clk["t"], sleep=lambda s: None,
    )
    for label, _ in ReliableDispatcher.strategies(desc):
        br.record_failure((label, "scan"))
    with pytest.raises(CircuitOpenError):
        dispatcher.offload(desc, _payload())


# --------------------------------------------------------- payload checksum


def test_payload_checksum_deterministic_and_structure_sensitive():
    x = _payload()
    assert payload_checksum(x) == payload_checksum(np.asarray(x).copy())
    assert payload_checksum(x) != payload_checksum(
        np.asarray(x).astype(np.int64)
    )
    assert payload_checksum(x) != payload_checksum(
        np.asarray(x).reshape(N, P)
    )
    assert payload_checksum({"a": x}) != payload_checksum([x])


def test_payload_checksum_detects_any_single_bit_small_leaf():
    a = np.arange(2048, dtype=np.int32)  # 8 KiB: full coverage
    base = payload_checksum(a)
    rng = np.random.default_rng(0)
    for byte in rng.integers(0, a.nbytes, 32):
        b = a.copy().view(np.uint8)
        b[byte] ^= 1 << int(rng.integers(0, 8))
        assert payload_checksum(b.view(np.int32)) != base


def test_payload_checksum_detects_slice_corruption_when_sampled():
    a = np.random.default_rng(1).integers(
        0, 1 << 20, size=(8, 131072), dtype=np.int32
    )  # 4 MiB: sampled coverage
    base = payload_checksum(a)
    nbytes = a.nbytes
    for start in (0, 12345, nbytes // 2, nbytes - nbytes // 32 - 64):
        # uniform-mask flip: the case a pure-xor fold provably misses
        b = a.copy().reshape(-1).view(np.uint8)
        b[start:start + nbytes // 32 + 64] ^= 0xFF
        assert payload_checksum(b.view(np.int32).reshape(a.shape)) != base
    # and on the all-zeros worst case for modular sums
    z = np.zeros_like(a)
    bz = z.copy().reshape(-1).view(np.uint8)
    bz[0:nbytes // 32 + 64] ^= 0xFF
    assert payload_checksum(bz.view(np.int32).reshape(a.shape)) != (
        payload_checksum(z)
    )


def test_checksum_full_coverage_env_override():
    a = np.random.default_rng(2).integers(
        0, 1 << 20, size=(8, 131072), dtype=np.int32
    )
    b = a.copy().reshape(-1).view(np.uint8)
    b[999_999] ^= 1  # an unsampled byte: invisible to the tiered fold
    b = b.view(np.int32).reshape(a.shape)
    assert payload_checksum(a) == payload_checksum(b)
    os.environ["REPRO_CHECKSUM_FULL"] = "1"
    _reset_full_coverage()
    try:
        assert payload_checksum(a) != payload_checksum(b)
        assert payload_checksum(a) == payload_checksum(a.copy())
    finally:
        del os.environ["REPRO_CHECKSUM_FULL"]
        _reset_full_coverage()


def test_verify_payload_raises_attributed_integrity_error():
    x = _payload()
    chk = payload_checksum(x)
    verify_payload(x, chk, request="t0#0")  # clean: no raise
    bad = np.asarray(x).copy()
    bad[3, 7] ^= 1
    with pytest.raises(IntegrityError) as ei:
        verify_payload(jnp.asarray(bad), chk, request="t0#0")
    assert ei.value.request == "t0#0"


# ------------------------------------------------- broker: bisection et al.


def test_broker_quarantines_poisoned_request_by_bisection():
    broker = DescriptorBroker(
        reliability=ReliabilityPolicy(
            retry=RetryPolicy(max_attempts=2, backoff_s=0.0)
        )
    )
    desc = _desc(broker)
    clients = [broker.client(f"t{i}") for i in range(4)]
    tickets = [c.submit(desc, _payload(i)) for i, c in enumerate(clients)]
    poisoned = 2
    bad = np.asarray(broker._queue[poisoned].payload).copy()
    bad[1, 5] ^= 1  # at rest, after the submit-time checksum
    broker._queue[poisoned].payload = jnp.asarray(bad)
    broker.drain()
    for i, t in enumerate(tickets):
        if i == poisoned:
            with pytest.raises(IntegrityError) as ei:
                t.result(timeout=10.0)
            assert ei.value.request == f"t{poisoned}#0"
        else:
            out = np.asarray(t.result(timeout=10.0))
            ref = np.asarray(broker.engine.offload(desc, _payload(i)))
            assert np.array_equal(out, ref)


def test_broker_reliability_off_has_no_dispatcher():
    broker = DescriptorBroker()
    assert broker.reliability is None and broker._dispatcher is None
    broker_on = DescriptorBroker(reliability=True)
    assert broker_on.reliability is not None
    assert isinstance(broker_on._dispatcher, ReliableDispatcher)


def test_ticket_result_default_timeout_is_finite():
    assert np.isfinite(DEFAULT_RESULT_TIMEOUT_S)
    broker = DescriptorBroker()
    t = broker.client("t0").submit(_desc(broker), _payload())
    # never drained: a finite wait must raise, not hang forever
    with pytest.raises(TimeoutError):
        t.result(timeout=0.05)
    broker.stop(drain=False)
    with pytest.raises(BrokerStopped):
        t.result(timeout=1.0)


# ------------------------------------------------- recovery-loop filtering


def test_failure_injector_dispatch_mode_is_deterministic():
    a = FailureInjector(rate=0.3, seed=5)
    b = FailureInjector(rate=0.3, seed=5)

    def verdicts(inj, n=50):
        out = []
        for _ in range(n):
            try:
                inj.check_dispatch()
                out.append(False)
            except SimulatedFailure:
                out.append(True)
        return out

    va, vb = verdicts(a), verdicts(b)
    assert va == vb and any(va) and not all(va)
    assert verdicts(FailureInjector(rate=0.3, seed=6)) != va
    assert not any(verdicts(FailureInjector(rate=0.0, seed=5)))


def test_failure_injector_exc_factory_substitutes():
    inj = FailureInjector(rate=1.0, seed=0,
                          exc_factory=lambda n: TransportError(f"msg {n}"))
    with pytest.raises(TransportError):
        inj.check_dispatch()


def test_is_recoverable_filters_reliability_faults():
    assert is_recoverable(SimulatedFailure("host died"))
    assert not is_recoverable(IntegrityError("checksum mismatch"))
    assert not is_recoverable(TransportError("chaos drop"))
    assert not is_recoverable(
        RetryExhaustedError("gone", last_error=TransportError("x"),
                            attempts=3)
    )
    assert not is_recoverable(CircuitOpenError("open"))
    assert not is_recoverable(ValueError("caller bug"))


# ----------------------------------------------------- wire-format fuzzing


def _checked_variants():
    base = dict(comm_size=8, coll_type=CollType.SCAN, count=N,
                data_type=WireDType.INT32)
    legacy = np.asarray(
        [7, 8, int(CollType.EXSCAN), 4, 3, 5, 2, int(WireDType.INT32),
         33, 0], dtype=np.uint32,
    )
    legacy_checked = np.concatenate(
        [legacy, np.asarray([wire_checksum(legacy)], dtype=np.uint32)]
    )
    return {
        11: legacy_checked,  # 10-word legacy + crc
        16: encode_checked(CollectiveDescriptor(**base, axes=(8,))),
        17: encode_checked(
            CollectiveDescriptor(**base, axes=(2, 4), optimized=True)
        ),
        18: encode_checked(
            CollectiveDescriptor(**base, axes=(2, 4), chunks=4)
        ),
    }


def test_checked_descriptor_lengths_cover_every_wire_layout():
    variants = _checked_variants()
    assert sorted(variants) == [11, 16, 17, 18]  # payload 10/15/16/17 + crc
    for words in variants.values():
        decode_checked(words)  # clean words decode


@pytest.mark.parametrize("nwords", sorted(_checked_variants()))
def test_wire_fuzz_bit_flips_never_decode_silently_different(nwords):
    """Flip every bit of every checked layout: decode_checked must either
    raise cleanly (IntegrityError for corruption, ValueError for a
    malformed field) or return a descriptor equal to the original —
    never silently decode to a different-but-valid one."""
    words = _checked_variants()[nwords]
    original = decode_checked(words)
    for w in range(nwords):
        for bit in range(32):
            fuzzed = words.copy()
            fuzzed[w] ^= np.uint32(1 << bit)
            try:
                got = decode_checked(fuzzed)
            except (IntegrityError, ValueError):
                continue
            assert got == original, (
                f"word {w} bit {bit}: silent decode to a different "
                f"descriptor"
            )


def test_truncated_checked_descriptor_rejected():
    words = _checked_variants()[16]
    with pytest.raises((IntegrityError, ValueError)):
        decode_checked(words[:-1])
    with pytest.raises((IntegrityError, ValueError)):
        decode_checked(words[:5])


# ------------------------------------------------------------- end to end


def test_chaos_check_end_to_end(subprocess_runner):
    out = subprocess_runner("repro.testing.chaos_check", "2", "2")
    assert "chaos_check_summary,bitwise_equal,1," in out
    assert "quarantine_ok,1,breaker_ok,1,healthz_ok,1" in out
