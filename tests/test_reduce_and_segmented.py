"""Segmented scans (Blelloch, paper refs [8,9]) and the descriptor's other
coll_types (Reduce/Allreduce/Barrier) on the same schedule machinery."""

import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing.hypothesis_compat import given, settings, strategies as st

from repro.core import ALGORITHMS, MAX, SUM, segmented_operator, sim_scan

GENERIC = [a for a in sorted(ALGORITHMS) if a != "invertible_doubling"]


def _seg_cumsum(vals, flags):
    out = np.zeros_like(vals)
    acc = 0.0
    for i, (v, f) in enumerate(zip(vals, flags)):
        acc = v if f else acc + v
        out[i] = acc
    return out


@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(1, 16),
    algo=st.sampled_from(GENERIC),
    data=st.data(),
)
def test_segmented_sum_matches_loop(p, algo, data):
    vals = np.asarray(
        data.draw(st.lists(st.floats(-4, 4, width=32), min_size=p, max_size=p)),
        np.float32,
    )
    flags = np.asarray(
        data.draw(st.lists(st.integers(0, 1), min_size=p, max_size=p)),
        np.float32,
    )
    op = segmented_operator(SUM)
    got, _ = sim_scan(
        (jnp.asarray(vals)[:, None], jnp.asarray(flags)), op, p, algorithm=algo
    )
    want = _seg_cumsum(vals, flags)
    np.testing.assert_allclose(np.asarray(got).ravel(), want, atol=1e-4)


def test_segmented_max():
    op = segmented_operator(MAX)
    vals = jnp.asarray([3.0, 1.0, 5.0, -2.0, 0.0, 4.0])[:, None]
    flags = jnp.asarray([1, 0, 0, 1, 0, 0], jnp.float32)
    got, _ = sim_scan((vals, flags), op, 6, algorithm="sklansky")
    np.testing.assert_allclose(
        np.asarray(got).ravel(), [3, 3, 5, -2, 0, 4], atol=0
    )


def test_segmented_associativity_property():
    """The lifted combine must be associative (schedule-independence)."""
    rng = np.random.default_rng(0)
    op = segmented_operator(SUM)
    for _ in range(50):
        elems = [
            (jnp.asarray(rng.normal(size=(2,)).astype(np.float32)),
             jnp.asarray(float(rng.integers(0, 2)), jnp.float32))
            for _ in range(3)
        ]
        a, b, c = elems
        left = op.combine(op.combine(a, b), c)
        right = op.combine(a, op.combine(b, c))
        np.testing.assert_allclose(np.asarray(left[0]), np.asarray(right[0]), atol=1e-5)
        np.testing.assert_allclose(np.asarray(left[1]), np.asarray(right[1]))


def test_reduce_allreduce_barrier_spmd(subprocess_runner):
    subprocess_runner("repro.testing.reduce_check")
