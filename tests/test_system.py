"""End-to-end system behaviour: train a small LM for real steps, serve it.

This is deliverable (b)'s guarantee in test form: the full stack (data
pipeline -> sharded step -> optimizer -> checkpointing -> serving engine)
works together, losses go down, generations are deterministic.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, batches
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import Trainer, TrainerConfig
from repro.serving.engine import Request, ServeEngine
from repro.sharding.specs import Topology


def test_train_then_serve(tmp_path):
    cfg = get_config("smollm_360m").reduced()
    api = build_model(cfg)
    B, S = 4, 32
    shape = ShapeConfig("tiny", S, B, "train")
    data = batches(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=S, global_batch=B, seed=3)
    )
    topo = Topology(mesh=None)
    tr = Trainer(
        api, topo, shape, data,
        TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=10, async_ckpt=False),
        AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=60),
    )
    params, opt = tr.init_state()
    params, opt, hist = tr.run(params, opt, num_steps=30)
    losses = [h["loss"] for h in hist]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
    assert all(np.isfinite(l) for l in losses)

    # ---- serve the trained params with continuous batching
    eng = ServeEngine(api, params, topo, batch_size=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(2, cfg.vocab_size, size=8).astype(np.int32),
                max_new_tokens=6)
        for i in range(3)
    ]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained(max_steps=200)
    for r in reqs:
        assert r.done and 1 <= len(r.generated) <= 6
        assert all(0 <= t < cfg.padded_vocab for t in r.generated)

    # determinism: same prompt through a fresh engine gives same tokens
    eng2 = ServeEngine(api, params, topo, batch_size=2, max_len=64)
    r2 = Request(rid=9, prompt=reqs[0].prompt, max_new_tokens=6)
    eng2.submit(r2)
    eng2.run_until_drained(max_steps=200)
    assert r2.generated == reqs[0].generated


def test_mamba_system_train():
    """The SSM family end-to-end (scan collective in the loss path)."""
    cfg = get_config("mamba2_130m").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    from repro.optim.adamw import adamw_update, init_opt_state
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=50)
    data = batches(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4, seed=5))

    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(api.loss, has_aux=True)(params, batch)
        p2, o2, _ = adamw_update(g, opt, params, ocfg)
        return p2, o2, loss

    losses = []
    for i in range(20):
        b = next(data)
        params, opt, loss = step(params, opt, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)
