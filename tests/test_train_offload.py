"""Offloaded training path: engine-dispatched DP step vs raw shard_map.

The heavy end-to-end scenarios (bitwise step equivalence on a 2x2 mesh,
planner-first remesh adoption, plan-vs-halving) run in a subprocess via
``repro.testing.train_offload_check`` (the multi-device CPU mesh must exist
before jax import). The in-process tests cover the build-time contracts.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.launch.steps import build_dp_train_step, build_train_step
from repro.models import build_model
from repro.sharding.specs import Topology, make_topology


def test_trainer_offload_end_to_end(subprocess_runner):
    """2-step DP trainer on a 2x2 (pod, data) mesh: gradient allreduce /
    metric means / example EXSCAN through OffloadEngine planned descriptors,
    bitwise-equal to the raw-lax shard_map baseline; step-2 dispatches hit
    the plan cache; an injected failure adopts plan_remesh's topology and
    repopulates the engine cache on the surviving mesh."""
    subprocess_runner("repro.testing.train_offload_check", "2", "2")


def test_build_train_step_flag_requires_engine():
    cfg = get_config("smollm_360m").reduced()
    api = build_model(cfg)
    shape = ShapeConfig("tiny", 16, 4, "train")
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("pod", "data"))
    topo = make_topology(mesh)
    with pytest.raises(ValueError, match="OffloadEngine"):
        build_train_step(api, topo, shape, use_offload_engine=True)


def test_build_train_step_flag_noop_without_mesh():
    cfg = get_config("smollm_360m").reduced()
    api = build_model(cfg)
    shape = ShapeConfig("tiny", 16, 4, "train")
    step, shapes, specs = build_train_step(
        api, Topology(mesh=None), shape, use_offload_engine=True
    )
    assert step is not None  # fell back to the jitted GSPMD path


def test_dp_step_rejects_tensor_parallel_mesh():
    cfg = get_config("smollm_360m").reduced()
    api = build_model(cfg)
    shape = ShapeConfig("tiny", 16, 4, "train")

    class _FakeTopo:
        mesh = object()
        model_size = 2

    with pytest.raises(ValueError, match="data-parallel only"):
        build_dp_train_step(api, _FakeTopo(), shape)


def test_make_topology_pure_dp_pod_mesh():
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("pod", "data")
    )
    topo = make_topology(mesh)
    assert topo.batch_axes == ("pod", "data")
    assert topo.model_axis is None
    assert topo.model_size == 1
    assert topo.dp_size == 1
