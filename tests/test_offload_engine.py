"""Offload-engine tests: descriptor dispatch for all five CollTypes, the
compiled-schedule cache (telemetry-proven), and the measured-cost tuning
table changing auto-selection vs the static TPU constants."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    MAX,
    SUM,
    TPU_V5E,
    CollType,
    CollectiveDescriptor,
    select_algorithm,
)
from repro.core.selector import set_active_tuning
from repro.offload import OffloadEngine, TuningCache, autotune

P = 8
N = 16


@pytest.fixture(autouse=True)
def _no_active_tuning():
    set_active_tuning(None)
    yield
    set_active_tuning(None)


def _payload(seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-5, 6, size=(P, N)).astype(np.float32))


def _descriptor(eng, coll, **kw):
    kw.setdefault("p", P)
    kw.setdefault("payload_bytes", N * 4)
    kw.setdefault("op", "sum")
    return eng.make_descriptor(coll, **kw)


# ------------------------------------------------------------------ dispatch


@pytest.mark.parametrize("coll", [c.name for c in CollType])
def test_all_colltypes_roundtrip_through_encoded_descriptor(coll):
    """encode() -> decode() -> correct sim-backend result, for every coll."""
    eng = OffloadEngine()
    x = _payload()
    xn = np.asarray(x)
    desc = _descriptor(eng, coll, root=3)
    words = desc.encode()
    assert CollectiveDescriptor.decode(words) == desc
    out = np.asarray(eng.offload(words, x))

    if coll == "SCAN":
        np.testing.assert_array_equal(out, np.cumsum(xn, axis=0))
    elif coll == "EXSCAN":
        want = np.concatenate([np.zeros((1, N), np.float32),
                               np.cumsum(xn, axis=0)[:-1]])
        np.testing.assert_array_equal(out, want)
    elif coll == "REDUCE":
        want = np.zeros_like(xn)
        want[3] = xn.sum(axis=0)
        np.testing.assert_allclose(out, want, atol=1e-5)
    elif coll == "ALLREDUCE":
        want = np.broadcast_to(xn.sum(axis=0), xn.shape)
        np.testing.assert_allclose(out, want, atol=1e-5)
    else:  # BARRIER
        np.testing.assert_array_equal(out, np.ones(P, np.float32))


def test_reduce_allreduce_other_ops_and_roots():
    eng = OffloadEngine()
    x = _payload(1)
    xn = np.asarray(x)
    out = np.asarray(
        eng.offload(_descriptor(eng, "REDUCE", op="max", root=P - 1), x)
    )
    assert np.array_equal(out[P - 1], xn.max(axis=0))
    out = np.asarray(eng.offload(_descriptor(eng, "ALLREDUCE", op="max"), x))
    np.testing.assert_array_equal(
        out, np.broadcast_to(xn.max(axis=0), xn.shape)
    )
    out = np.asarray(eng.offload(_descriptor(eng, "ALLREDUCE", op="min"), x))
    np.testing.assert_array_equal(
        out, np.broadcast_to(xn.min(axis=0), xn.shape)
    )


def test_nonpow2_allreduce_dispatch():
    eng = OffloadEngine()
    p = 6
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(p, 4)).astype(np.float32))
    desc = eng.make_descriptor("ALLREDUCE", p=p, payload_bytes=16, op="sum")
    out = np.asarray(eng.offload(desc, x))
    np.testing.assert_allclose(
        out, np.broadcast_to(np.asarray(x).sum(axis=0), (p, 4)), atol=1e-5
    )


# ------------------------------------------------------------------- caching


def test_schedule_cache_hits_on_repeat_offloads():
    eng = OffloadEngine()
    x = _payload()
    desc = _descriptor(eng, "SCAN", algorithm="hillis_steele")
    eng.offload(desc, x)
    assert (eng.telemetry.hits, eng.telemetry.misses) == (0, 1)
    for _ in range(4):
        eng.offload(desc, x)
    assert (eng.telemetry.hits, eng.telemetry.misses) == (4, 1)
    assert eng.telemetry.compiles == 1
    assert eng.cache_size() == 1
    assert eng.telemetry.hit_rate == pytest.approx(0.8)
    # latency telemetry is recorded in host-dispatch (sim) mode
    assert eng.telemetry.timed_dispatches == 5
    assert eng.telemetry.mean_latency_s > 0


def test_cache_key_ignores_rank_and_msg_type_but_not_schedule_fields():
    import dataclasses

    eng = OffloadEngine()
    x = _payload()
    base = _descriptor(eng, "SCAN", algorithm="hillis_steele")
    eng.offload(base, x)
    # other ranks of the same communicator share the compiled schedule
    eng.offload(dataclasses.replace(base, rank=5), x)
    assert (eng.telemetry.hits, eng.telemetry.misses) == (1, 1)
    # a different algorithm is a different schedule
    eng.offload(dataclasses.replace(base, algo_type="binomial_tree"), x)
    assert (eng.telemetry.hits, eng.telemetry.misses) == (1, 2)
    # as is a different coll_type
    eng.offload(dataclasses.replace(base, coll_type=CollType.ALLREDUCE), x)
    assert (eng.telemetry.hits, eng.telemetry.misses) == (1, 3)
    assert eng.cache_size() == 3


def test_clear_resets_cache_size_gauge_and_counts_clears():
    """A remesh-triggered clear must zero the cache_size gauge immediately
    (not at the next dispatch) and bump the cache_clears counter."""
    eng = OffloadEngine()
    x = _payload()
    eng.offload(_descriptor(eng, "SCAN"), x)
    eng.offload(_descriptor(eng, "ALLREDUCE"), x)
    assert eng.telemetry.snapshot()["cache_size"] == 2
    assert eng.telemetry.snapshot()["cache_clears"] == 0
    eng.clear()
    snap = eng.telemetry.snapshot()
    assert snap["cache_size"] == 0          # reset at clear time
    assert snap["cache_clears"] == 1
    eng.clear()
    assert eng.telemetry.snapshot()["cache_clears"] == 2
    # repopulation reports the rebuilt size
    eng.offload(_descriptor(eng, "SCAN"), x)
    snap = eng.telemetry.snapshot()
    assert snap["cache_size"] == 1 and snap["cache_clears"] == 2


def test_per_coll_telemetry_counters():
    eng = OffloadEngine()
    x = _payload()
    for coll in ("SCAN", "SCAN", "EXSCAN", "BARRIER"):
        eng.offload(_descriptor(eng, coll), x)
    assert eng.telemetry.calls_by_coll == {
        "scan": 2, "exscan": 1, "barrier": 1,
    }


def test_sim_payload_validation():
    eng = OffloadEngine()
    desc = _descriptor(eng, "SCAN")
    bad = jnp.zeros((P + 1, N), jnp.float32)
    with pytest.raises(ValueError, match="leading rank axis"):
        eng.offload(desc, bad)
    with pytest.raises(ValueError, match="requires a payload"):
        eng.offload(desc, None)


# -------------------------------------------------------------- auto tuning


def _synthetic_cache() -> TuningCache:
    """A tuning table whose measurements say sequential_pipelined wins at
    (p=4, 1 KiB) — which the static TPU model never selects there."""
    cache = TuningCache(backend="synthetic")
    grid = [(2, 1024), (4, 1024), (8, 1024), (4, 65536)]
    for p, msg in grid:
        for algo, t in [
            ("hillis_steele", 50e-6),
            ("sequential_pipelined", 10e-6 if (p, msg) == (4, 1024) else 90e-6),
            ("recursive_doubling", 70e-6),
            ("binomial_tree", 80e-6),
        ]:
            cache.record("scan", algo, p, msg, t)
    return cache


def test_tuned_table_changes_selection_vs_static():
    static = select_algorithm(4, 1024, SUM)
    assert static == "hillis_steele"
    cache = _synthetic_cache()
    cache.activate()
    assert select_algorithm(4, 1024, SUM) == "sequential_pipelined"
    # off-grid-but-near queries snap to the nearest measured winner
    assert select_algorithm(4, 2048, SUM) == "sequential_pipelined"
    # elsewhere on the grid the measured winner rules
    assert select_algorithm(8, 1024, SUM) == "hillis_steele"
    set_active_tuning(None)
    assert select_algorithm(4, 1024, SUM) == static


def test_tuned_winner_must_be_applicable_to_op():
    cache = TuningCache(backend="synthetic")
    cache.record("scan", "invertible_doubling", 8, 1024, 1e-6)
    cache.record("scan", "hillis_steele", 8, 1024, 5e-6)
    cache.activate()
    # MAX has no inverse: the invertible winner is skipped, static fallback
    assert select_algorithm(8, 1024, MAX) != "invertible_doubling"
    # SUM may use it
    assert select_algorithm(8, 1024, SUM) == "invertible_doubling"


def test_tuning_cache_json_roundtrip(tmp_path):
    cache = _synthetic_cache()
    fitted = cache.fitted_model()
    assert fitted is not None and fitted.alpha > 0
    path = cache.save(tmp_path / "table.json")
    loaded = TuningCache.load(path)
    assert loaded.winners == cache.winners
    assert loaded.lookup(4, 1024, "scan") == "sequential_pipelined"
    lf = loaded.fitted_model()
    assert lf is not None
    assert lf.alpha == pytest.approx(fitted.alpha)
    assert lf.beta == pytest.approx(fitted.beta)


def test_live_autotune_produces_winners_and_fit():
    cache = autotune(
        ps=(2, 4), payloads=(256,), colls=("scan",), iters=2
    )
    assert len(cache.measurements) >= 8
    assert cache.winners  # every grid point has a measured winner
    assert cache.fitted_model() is not None
    assert cache.lookup(4, 256, "scan") in {
        "sequential", "sequential_pipelined", "hillis_steele",
        "recursive_doubling", "binomial_tree", "sklansky",
        "invertible_doubling",
    }


def test_live_tuned_selection_diverges_from_static_somewhere():
    """The acceptance check: measured costs on this backend change at least
    one grid-point selection vs the static TPU constants."""
    cache = autotune(
        ps=(2, 4, 8), payloads=(1024, 16384), colls=("scan", "exscan"),
        iters=3,
    )
    cache.activate()
    changed = 0
    for coll in ("scan", "exscan"):
        for p in (2, 4, 8):
            for msg in (1024, 16384):
                tuned = select_algorithm(p, msg, SUM, coll=coll)
                static = select_algorithm(p, msg, SUM, model=TPU_V5E, coll=coll)
                changed += int(tuned != static)
    assert changed >= 1


# ----------------------------------------------------------------- descriptor


def test_make_descriptor_auto_resolves_algorithm():
    eng = OffloadEngine()
    desc = eng.make_descriptor("SCAN", p=16, payload_bytes=1024, op="sum")
    assert desc.algo_type != "auto"
    assert desc.comm_size == 16
    # and the resolved descriptor still round-trips the wire format
    assert CollectiveDescriptor.decode(desc.encode()) == desc


def test_make_descriptor_auto_uses_each_colls_own_table():
    """REDUCE/ALLREDUCE/BARRIER auto-selection must consult their own coll
    kind's measured winners, not the scan table."""
    cache = TuningCache(backend="synthetic")
    grid = {
        "scan": "hillis_steele",
        "exscan": "sklansky",
        "reduce": "binomial_tree",
        "allreduce": "recursive_doubling",
        "barrier": "sequential_pipelined",
    }
    for coll, winner in grid.items():
        cache.record(coll, winner, 8, 64, 1e-6)
        cache.record(coll, "sequential", 8, 64, 9e-6)
    cache.activate()
    eng = OffloadEngine()
    for coll, winner in grid.items():
        desc = eng.make_descriptor(coll.upper(), p=8, payload_bytes=64)
        assert desc.algo_type == winner, (coll, desc.algo_type)


def test_autotune_grid_covers_all_five_colls():
    cache = autotune(
        ps=(2, 4),
        payloads=(256,),
        colls=("scan", "exscan", "reduce", "allreduce", "barrier"),
        algorithms=("hillis_steele", "binomial_tree"),
        iters=1,
    )
    colls_measured = {m.coll for m in cache.measurements}
    assert colls_measured == {
        "scan", "exscan", "reduce", "allreduce", "barrier",
    }
    for coll in colls_measured:
        assert cache.lookup(4, 256, coll) in {
            "hillis_steele", "binomial_tree",
        }
