"""Collective-planner tests: N-level plans for every CollType vs the flat
single-axis reference (bitwise), tuned axis splits, descriptor topology
round-trips, planned engine dispatch, and the fault-driven re-plan hook.

Bitwise equality across different combine trees requires exact arithmetic;
the value strategies below stick to integers and powers of two (and, for
flash, a shared running max so every rescale factor is exactly 1.0), so any
association of the operator gives identical bits.
"""

import dataclasses
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    SSD,
    CollType,
    CollectiveDescriptor,
    get_operator,
    sim_allreduce,
    sim_barrier,
    sim_reduce,
    sim_scan,
)
from repro.core.selector import set_active_tuning
from repro.offload import (
    OffloadEngine,
    PlanLayout,
    TuningCache,
    build_plan,
    lower_sim,
    plan_axis_order,
    plan_cost,
    plan_layout,
    tune_splits,
)
from repro.sharding.specs import plan_spec
from repro.testing.hypothesis_compat import given, settings, strategies as st

MESHES_2D = [(2, 4), (4, 2), (3, 3), (2, 2)]
MESHES_3D = [(2, 2, 2), (2, 3, 2), (3, 2, 2)]


@pytest.fixture(autouse=True)
def _no_active_tuning():
    set_active_tuning(None)
    yield
    set_active_tuning(None)


def _flat_reference(coll, x, p, *, root=0):
    if coll == "SCAN":
        return sim_scan(x, "sum", p, algorithm="hillis_steele")
    if coll == "EXSCAN":
        return sim_scan(
            x, "sum", p, algorithm="hillis_steele", inclusive=False
        )
    if coll == "REDUCE":
        return sim_reduce(x, "sum", p, root=root)
    if coll == "ALLREDUCE":
        return sim_allreduce(x, "sum", p)
    return sim_barrier(p)


# ----------------------------------------------------------- plan vs flat


@pytest.mark.parametrize("sizes", MESHES_2D + MESHES_3D)
@pytest.mark.parametrize("coll", [c.name for c in CollType])
def test_planned_matches_flat_bitwise_all_colltypes(sizes, coll):
    """Every CollType, every 2D/3D mesh shape: the planned result equals the
    flat single-axis reference bit for bit (integer payloads)."""
    p = int(np.prod(sizes))
    rng = np.random.default_rng(p * 7 + len(sizes))
    x = jnp.asarray(rng.integers(-6, 7, size=(p, 5)).astype(np.float32))
    root = p - 2 if p > 2 else 0
    plan = build_plan(coll, sizes, "sum", 20, order="auto", root=root)
    got = lower_sim(plan)(None if coll == "BARRIER" else x)
    want = _flat_reference(coll, x, p, root=root)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("sizes", [(2, 4), (2, 2, 2)])
def test_planned_every_split_same_result(sizes):
    """All axis orders of one mesh produce the same (flat-reference) bits —
    the split changes the schedule, never the answer."""
    import itertools

    p = int(np.prod(sizes))
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(-5, 6, size=(p, 4)).astype(np.float32))
    want = np.asarray(sim_scan(x, "sum", p, algorithm="hillis_steele"))
    for order in itertools.permutations(range(len(sizes))):
        plan = build_plan("SCAN", sizes, "sum", 16, order=order)
        got = np.asarray(lower_sim(plan)(x))
        np.testing.assert_array_equal(got, want, err_msg=f"order={order}")


def test_reduce_root_placement_off_rank_zero():
    for sizes in [(2, 4), (2, 2, 2), (3, 3)]:
        p = int(np.prod(sizes))
        rng = np.random.default_rng(p)
        x = jnp.asarray(rng.integers(-9, 10, size=(p, 3)).astype(np.float32))
        for root in range(p):
            plan = build_plan("REDUCE", sizes, "sum", 12, root=root)
            got = np.asarray(lower_sim(plan)(x))
            want = np.asarray(sim_reduce(x, "sum", p, root=root))
            np.testing.assert_array_equal(
                got, want, err_msg=f"sizes={sizes} root={root}"
            )


def test_reduce_off_root_under_non_identity_split():
    """REDUCE to an off-rank-0 root with every *non-identity* axis order —
    the trainer-path edge case: the split must not move the root."""
    import itertools

    for sizes in [(2, 4), (2, 2, 2), (3, 2, 2)]:
        p = int(np.prod(sizes))
        rng = np.random.default_rng(p * 13)
        x = jnp.asarray(rng.integers(-7, 8, size=(p, 4)).astype(np.float32))
        orders = [
            o
            for o in itertools.permutations(range(len(sizes)))
            if o != tuple(range(len(sizes)))
        ]
        for order in orders:
            for root in (1, p - 2, p - 1):
                plan = build_plan(
                    "REDUCE", sizes, "sum", 16, order=order, root=root
                )
                got = np.asarray(lower_sim(plan)(x))
                want = np.asarray(sim_reduce(x, "sum", p, root=root))
                np.testing.assert_array_equal(
                    got, want, err_msg=f"sizes={sizes} order={order} "
                    f"root={root}"
                )


def test_exscan_3d_mesh_bitwise_all_orders():
    """EXSCAN over 3D meshes, every axis order, vs the flat single-axis
    reference — bit for bit (integer payloads)."""
    import itertools

    for sizes in MESHES_3D:
        p = int(np.prod(sizes))
        rng = np.random.default_rng(p * 31)
        x = jnp.asarray(rng.integers(-6, 7, size=(p, 5)).astype(np.float32))
        want = np.asarray(
            sim_scan(x, "sum", p, algorithm="hillis_steele", inclusive=False)
        )
        for order in itertools.permutations(range(3)):
            plan = build_plan("EXSCAN", sizes, "sum", 20, order=order)
            got = np.asarray(lower_sim(plan)(x))
            np.testing.assert_array_equal(
                got, want, err_msg=f"sizes={sizes} order={order}"
            )


# -------------------------------------------- hypothesis: non-commutative


@settings(max_examples=24, deadline=None)
@given(
    mesh_idx=st.integers(0, 4),
    inclusive=st.booleans(),
    seed=st.integers(0, 10_000),
)
def test_planned_ssd_bitwise_equivalence(mesh_idx, inclusive, seed):
    """SSD (non-commutative (decay, state) recurrence): planned == flat
    bitwise, using exact arithmetic (power-of-two decays, integer states)."""
    sizes = [(2, 4), (4, 2), (2, 2, 2), (3, 2), (2, 3, 2)][mesh_idx]
    p = int(np.prod(sizes))
    rng = np.random.default_rng(seed)
    a = jnp.asarray(
        rng.choice([0.5, 1.0, 2.0], size=(p, 4)).astype(np.float32)
    )
    b = jnp.asarray(rng.integers(-4, 5, size=(p, 4)).astype(np.float32))
    coll = "SCAN" if inclusive else "EXSCAN"
    plan = build_plan(coll, sizes, SSD, 32, order="auto")
    ga, gb = lower_sim(plan, SSD)((a, b))
    wa, wb = sim_scan(
        (a, b), SSD, p, algorithm="hillis_steele", inclusive=inclusive
    )
    np.testing.assert_array_equal(np.asarray(ga), np.asarray(wa))
    np.testing.assert_array_equal(np.asarray(gb), np.asarray(wb))


@settings(max_examples=16, deadline=None)
@given(
    mesh_idx=st.integers(0, 3),
    inclusive=st.booleans(),
    m_val=st.integers(-3, 3),
    seed=st.integers(0, 10_000),
)
def test_planned_flash_bitwise_equivalence(mesh_idx, inclusive, m_val, seed):
    """Flash-attention combine (m, l, o): with a shared running max every
    rescale is exp(0) == 1.0 exactly, so planned == flat bitwise."""
    sizes = [(2, 4), (4, 2), (2, 2, 2), (2, 3)][mesh_idx]
    p = int(np.prod(sizes))
    flash = get_operator("flash")
    rng = np.random.default_rng(seed)
    m = jnp.full((p, 4), float(m_val), jnp.float32)
    l = jnp.asarray(rng.integers(1, 6, size=(p, 4)).astype(np.float32))
    o = jnp.asarray(rng.integers(-5, 6, size=(p, 4)).astype(np.float32))
    coll = "SCAN" if inclusive else "EXSCAN"
    plan = build_plan(coll, sizes, flash, 48, order="auto")
    got = lower_sim(plan, flash)((m, l, o))
    want = sim_scan(
        (m, l, o), flash, p, algorithm="hillis_steele", inclusive=inclusive
    )
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@settings(max_examples=12, deadline=None)
@given(
    mesh_idx=st.integers(0, 2),
    root_frac=st.integers(0, 100),
    seed=st.integers(0, 10_000),
)
def test_planned_reduce_ssd_any_root(mesh_idx, root_frac, seed):
    """REDUCE of the non-commutative SSD operator to an arbitrary root."""
    sizes = [(2, 4), (2, 2, 2), (3, 2)][mesh_idx]
    p = int(np.prod(sizes))
    root = root_frac % p
    rng = np.random.default_rng(seed)
    a = jnp.asarray(
        rng.choice([0.5, 1.0, 2.0], size=(p, 3)).astype(np.float32)
    )
    b = jnp.asarray(rng.integers(-3, 4, size=(p, 3)).astype(np.float32))
    plan = build_plan("REDUCE", sizes, SSD, 24, root=root)
    ga, gb = lower_sim(plan, SSD)((a, b))
    wa, wb = sim_reduce((a, b), SSD, p, root=root)
    np.testing.assert_array_equal(np.asarray(ga), np.asarray(wa))
    np.testing.assert_array_equal(np.asarray(gb), np.asarray(wb))


# ------------------------------------------------------- tuned axis split


def test_plan_axis_order_is_a_permutation_and_deterministic():
    for sizes in [(2, 4), (4, 2), (2, 2, 2), (8, 2)]:
        order = plan_axis_order("SCAN", sizes, 1024)
        assert sorted(order) == list(range(len(sizes)))
        assert order == plan_axis_order("SCAN", sizes, 1024)


def test_split_winner_overrides_model_choice():
    """A measured split winner in the active table rules over the cost
    model's preference."""
    model_choice = plan_axis_order("SCAN", (2, 4), 1024)
    forced = tuple(reversed(model_choice))
    cache = TuningCache(backend="synthetic")
    cache.record_split("scan", (2, 4), forced, 1024, 1e-6)
    cache.record_split("scan", (2, 4), model_choice, 1024, 9e-6)
    cache.activate()
    assert plan_axis_order("SCAN", (2, 4), 1024) == forced
    # nearby payloads snap to the measured winner too
    assert plan_axis_order("SCAN", (2, 4), 2048) == forced
    # a shape never split-tuned falls back to the model
    assert sorted(plan_axis_order("SCAN", (2, 2, 2), 1024)) == [0, 1, 2]
    set_active_tuning(None)
    assert plan_axis_order("SCAN", (2, 4), 1024) == model_choice


def test_tune_splits_records_winners_and_json_roundtrip(tmp_path):
    cache = tune_splits(
        topologies=[(2, 2)], payloads=(256,), colls=("scan",), iters=1
    )
    assert ("scan", (2, 2), 256) in cache.split_winners
    winner = cache.split_winner("scan", (2, 2), 256)
    assert winner in [(0, 1), (1, 0)]
    path = cache.save(tmp_path / "table.json")
    loaded = TuningCache.load(path)
    assert loaded.split_winners == cache.split_winners
    # the recorded winner is the measured minimum over all orders
    by_order = {
        m.order: m.seconds
        for m in cache.split_measurements
        if (m.coll, m.sizes, m.payload_bytes) == ("scan", (2, 2), 256)
    }
    assert by_order[winner] == min(by_order.values())


def test_plan_cost_positive_and_order_sensitive():
    plan_a = build_plan("SCAN", (2, 8), "sum", 4096, order=(0, 1))
    plan_b = build_plan("SCAN", (2, 8), "sum", 4096, order=(1, 0))
    assert plan_cost(plan_a, 4096) > 0
    assert plan_cost(plan_b, 4096) > 0
    assert plan_cost(plan_a, 4096) != plan_cost(plan_b, 4096)


def test_build_plan_validation():
    with pytest.raises(ValueError, match="permutation"):
        build_plan("SCAN", (2, 4), "sum", 16, order=(0, 0))
    with pytest.raises(ValueError, match="root"):
        build_plan("REDUCE", (2, 4), "sum", 16, root=99)
    with pytest.raises(ValueError, match="mesh axes"):
        build_plan("SCAN", (2, 2, 2, 2), "sum", 16)


# ----------------------------------------------------- plan layout helper


@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(1, 3),
    perm_idx=st.integers(0, 5),
    sizes_seed=st.integers(0, 1000),
)
def test_plan_layout_roundtrip_property(k, perm_idx, sizes_seed):
    """layout.to_logical o layout.to_physical == identity (and vice versa)
    for every permutation of <= 3 axes."""
    import itertools

    rng = np.random.default_rng(sizes_seed)
    sizes = tuple(int(s) for s in rng.integers(1, 5, size=k))
    perms = list(itertools.permutations(range(k)))
    order = perms[perm_idx % len(perms)]
    layout = PlanLayout(sizes=sizes, order=order)
    p = int(np.prod(sizes))
    x = rng.normal(size=(p, 3)).astype(np.float32)
    np.testing.assert_array_equal(layout.to_logical(layout.to_physical(x)), x)
    np.testing.assert_array_equal(layout.to_physical(layout.to_logical(x)), x)
    # the flat permutation agrees with the reshape/transpose path
    perm = layout.permutation()
    assert sorted(perm.tolist()) == list(range(p))
    np.testing.assert_array_equal(x[perm], layout.to_physical(x))


def test_plan_layout_from_plan_and_descriptor():
    plan = build_plan("SCAN", (2, 4), "sum", 16, order=(1, 0))
    layout = plan_layout(plan)
    assert layout.sizes == (2, 4)
    assert layout.order == (1, 0)
    assert layout.logical_sizes == (4, 2)
    assert layout.inverse == (1, 0)
    d = CollectiveDescriptor(
        comm_size=8, coll_type=CollType.SCAN, algo_type="hillis_steele",
        axes=(2, 2, 2), split=(1, 2, 0),
    )
    dl = plan_layout(d)
    assert dl.sizes == (2, 2, 2) and dl.order == (1, 2, 0)
    # identity order when the descriptor carries no split
    d2 = CollectiveDescriptor(
        comm_size=8, coll_type=CollType.SCAN, algo_type="hillis_steele",
        axes=(2, 4),
    )
    assert plan_layout(d2).order == (0, 1)
    with pytest.raises(ValueError, match="permutation"):
        PlanLayout(sizes=(2, 4), order=(1, 1))
    with pytest.raises(ValueError, match="topology"):
        plan_layout(object())


def test_plan_spec_orders_axes_logically():
    layout = PlanLayout(sizes=(2, 2, 2), order=(1, 2, 0))
    spec = plan_spec(layout, ("pod", "outer", "inner"), ndim=2)
    assert tuple(spec) == (("outer", "inner", "pod"), None)
    single = PlanLayout(sizes=(4,), order=(0,))
    assert tuple(plan_spec(single, ("r",), ndim=1)) == ("r",)
    with pytest.raises(ValueError, match="cover"):
        plan_spec(layout, ("pod", "outer"))


# -------------------------------------------- descriptor topology encoding


def test_descriptor_topology_roundtrip():
    d = CollectiveDescriptor(
        comm_size=8,
        coll_type=CollType.SCAN,
        algo_type="hillis_steele",
        axes=(2, 2, 2),
        split=(1, 2, 0),
    )
    assert CollectiveDescriptor.decode(d.encode()) == d
    assert len(d.encode()) == 16


def test_descriptor_legacy_ten_word_decode():
    d = CollectiveDescriptor(comm_size=8, algo_type="hillis_steele")
    legacy = d.encode()[:10]
    assert CollectiveDescriptor.decode(legacy) == d


def test_descriptor_topology_validation():
    with pytest.raises(ValueError, match="factor"):
        CollectiveDescriptor(comm_size=8, axes=(2, 3))
    with pytest.raises(ValueError, match="permutation"):
        CollectiveDescriptor(comm_size=8, axes=(2, 4), split=(1, 1))
    with pytest.raises(ValueError, match="without axes"):
        CollectiveDescriptor(comm_size=8, split=(0, 1))


# ---------------------------------------------------- engine planned path


def test_engine_planned_dispatch_and_cache():
    eng = OffloadEngine()
    p = 8
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-5, 6, size=(p, 6)).astype(np.float32))
    desc = eng.make_descriptor(
        "SCAN", axes=(2, 2, 2), payload_bytes=24, op="sum"
    )
    assert desc.axes == (2, 2, 2)
    assert sorted(desc.split) == [0, 1, 2]
    assert CollectiveDescriptor.decode(desc.encode()) == desc
    want = np.asarray(sim_scan(x, "sum", p, algorithm="hillis_steele"))
    out = np.asarray(eng.offload(desc.encode(), x))
    np.testing.assert_array_equal(out, want)
    assert (eng.telemetry.hits, eng.telemetry.misses) == (0, 1)
    out = np.asarray(eng.offload(desc, x))
    np.testing.assert_array_equal(out, want)
    assert (eng.telemetry.hits, eng.telemetry.misses) == (1, 1)
    assert eng.telemetry.snapshot()["cache_size"] == 1
    # the cache keys on the plan, not the words: a reversed split of the
    # symmetric 2x2x2 mesh yields the identical logical plan -> cache HIT
    other = dataclasses.replace(desc, split=tuple(reversed(desc.split)))
    eng.offload(other, x)
    assert (eng.telemetry.hits, eng.telemetry.misses) == (2, 1)
    assert eng.telemetry.snapshot()["cache_size"] == 1
    # a split that changes the logical shape is a different compiled plan
    d24 = eng.make_descriptor(
        "SCAN", axes=(2, 4), payload_bytes=24, op="sum", split=(0, 1)
    )
    d42 = dataclasses.replace(
        d24, axes=(4, 2), split=(0, 1)  # logical (2, 4) -> (4, 2): distinct
    )
    eng.offload(d24, x)
    eng.offload(d42, x)
    assert eng.telemetry.misses == 3
    assert eng.telemetry.snapshot()["cache_size"] == 3


def test_engine_planned_all_colltypes_match_flat():
    eng = OffloadEngine()
    axes = (2, 2, 2)
    p = 8
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(-5, 6, size=(p, 4)).astype(np.float32))
    for coll in CollType:
        desc = eng.make_descriptor(
            coll.name, axes=axes, payload_bytes=16, op="sum", root=5
        )
        got = np.asarray(
            eng.offload(desc, None if coll == CollType.BARRIER else x)
        )
        want = np.asarray(_flat_reference(coll.name, x, p, root=5))
        np.testing.assert_array_equal(got, want, err_msg=coll.name)
    snap = eng.telemetry.snapshot()
    assert snap["cache_size"] == len(CollType)
    assert set(snap["latency_by_coll_us"]) == {
        c.name.lower() for c in CollType
    }
    assert all(v > 0 for v in snap["latency_by_coll_us"].values())


def test_engine_telemetry_latency_by_coll():
    eng = OffloadEngine()
    x = jnp.ones((4, 2), jnp.float32)
    d1 = eng.make_descriptor("SCAN", p=4, payload_bytes=8)
    d2 = eng.make_descriptor("ALLREDUCE", p=4, payload_bytes=8)
    for _ in range(3):
        eng.offload(d1, x)
    eng.offload(d2, x)
    snap = eng.telemetry.snapshot()
    assert snap["calls_by_coll"] == {"scan": 3, "allreduce": 1}
    assert snap["latency_by_coll_us"]["scan"] > 0
    assert snap["latency_by_coll_us"]["allreduce"] > 0
    assert snap["cache_size"] == 2


# ------------------------------------------------ fingerprint-checked load


def test_load_compatible_rejects_foreign_backend_with_warning(tmp_path):
    cache = TuningCache(backend="cuda:H100:x86_64")
    cache.record("scan", "hillis_steele", 4, 1024, 5e-6)
    path = cache.save(tmp_path / "foreign.json")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        loaded = TuningCache.load_compatible(path)
    assert loaded is None
    assert any("backend" in str(w.message) for w in caught)
    # strict load still works regardless of fingerprint
    strict = TuningCache.load(path)
    assert strict.backend == "cuda:H100:x86_64"


def test_load_compatible_accepts_same_backend(tmp_path):
    cache = TuningCache()  # current backend fingerprint
    cache.record("scan", "hillis_steele", 4, 1024, 5e-6)
    path = cache.save(tmp_path / "native.json")
    loaded = TuningCache.load_compatible(path)
    assert loaded is not None
    assert loaded.winners == cache.winners


# ------------------------------------------------- fault-driven re-planning


def test_remesh_triggers_replan_and_retune():
    from repro.launch.offload_runtime import (
        build_offload_engine,
        detach_remesh_hook,
    )
    from repro.core.selector import get_active_tuning
    from repro.runtime.fault import notify_remesh, plan_remesh

    eng = build_offload_engine(
        retune_on_remesh=True, remesh_tune_budget_s=0.05
    )
    try:
        x = jnp.ones((4, 2), jnp.float32)
        eng.offload(eng.make_descriptor("SCAN", p=4, payload_bytes=8), x)
        assert eng.cache_size() == 1
        before = get_active_tuning()
        # planning alone is a pure feasibility query — nothing invalidated
        assert plan_remesh(4, 2, lost_hosts=1) == (2, 2)
        assert eng.cache_size() == 1
        # *adopting* the plan fires the listeners
        notify_remesh((4, 2), (2, 2))
        assert eng.cache_size() == 0
        assert eng.telemetry.snapshot()["cache_size"] == 0
        after = get_active_tuning()
        assert after is not None and after is not before
        assert len(after.measurements) >= 1
    finally:
        detach_remesh_hook(eng)
        set_active_tuning(None)


def test_planner_spmd_3d_mesh(subprocess_runner):
    """All five CollTypes, engine-dispatched as planned descriptors inside
    shard_map on a real 2x2x2 (pod, outer, inner) device mesh."""
    subprocess_runner("repro.testing.planner_check", "2", "2", "2")


def test_detached_hook_no_longer_fires():
    from repro.launch.offload_runtime import (
        build_offload_engine,
        detach_remesh_hook,
    )
    from repro.runtime.fault import notify_remesh

    eng = build_offload_engine(
        retune_on_remesh=True, remesh_tune_budget_s=0.05
    )
    detach_remesh_hook(eng)
    x = jnp.ones((4, 2), jnp.float32)
    eng.offload(eng.make_descriptor("SCAN", p=4, payload_bytes=8), x)
    notify_remesh((4, 2), (2, 2))
    assert eng.cache_size() == 1  # untouched
