"""Two-level (2D-mesh) hierarchical scans vs the flat single-axis reference.

The acceptance bar: bitwise equality with the flat scan for sum/max on the
sim backend, plus the SPMD realization on a real 2D device mesh (subprocess,
so the device count is set before jax initializes)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import SSD, sim_scan
from repro.offload import flat_equivalent, sim_hierarchical_scan

SHAPES = [(2, 4), (4, 4), (3, 5), (4, 2), (2, 8)]


def _stacked(po, pi, n=8, seed=0, integer=True):
    rng = np.random.default_rng(seed)
    if integer:
        x = rng.integers(-6, 7, size=(po, pi, n)).astype(np.float32)
    else:
        x = rng.normal(size=(po, pi, n)).astype(np.float32)
    return jnp.asarray(x)


@pytest.mark.parametrize("po,pi", SHAPES)
@pytest.mark.parametrize("opname", ["sum", "max"])
def test_hierarchical_matches_flat_bitwise(po, pi, opname):
    x = _stacked(po, pi, integer=(opname == "sum"), seed=po * 31 + pi)
    got = sim_hierarchical_scan(x, opname, po, pi)
    want = sim_scan(
        flat_equivalent(x, po, pi), opname, po * pi,
        algorithm="hillis_steele",
    )
    np.testing.assert_array_equal(
        np.asarray(got).reshape(po * pi, -1), np.asarray(want)
    )


@pytest.mark.parametrize("po,pi", [(2, 4), (3, 3)])
def test_hierarchical_exclusive_matches_flat_bitwise(po, pi):
    x = _stacked(po, pi, seed=5)
    got = sim_hierarchical_scan(x, "sum", po, pi, inclusive=False)
    want = sim_scan(
        flat_equivalent(x, po, pi), "sum", po * pi,
        algorithm="hillis_steele", inclusive=False,
    )
    np.testing.assert_array_equal(
        np.asarray(got).reshape(po * pi, -1), np.asarray(want)
    )


def test_hierarchical_int32_exact():
    po, pi, n = 4, 4, 6
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(-100, 100, size=(po, pi, n)).astype(np.int32))
    got = sim_hierarchical_scan(x, "sum", po, pi)
    want = np.cumsum(np.asarray(x).reshape(po * pi, n), axis=0)
    np.testing.assert_array_equal(
        np.asarray(got).reshape(po * pi, n), want
    )


def test_hierarchical_ssd_non_commutative():
    """The SSD (decay, state) recurrence must respect rank order across the
    outer/inner split."""
    po, pi, n = 2, 4, 8
    ptotal = po * pi
    rng = np.random.default_rng(11)
    a = rng.uniform(0.5, 1.0, size=(po, pi, n)).astype(np.float32)
    b = rng.normal(size=(po, pi, n)).astype(np.float32)
    ga, gb = sim_hierarchical_scan(
        (jnp.asarray(a), jnp.asarray(b)), SSD, po, pi
    )
    af, bf = a.reshape(ptotal, n), b.reshape(ptotal, n)
    A = np.empty_like(af)
    B = np.empty_like(bf)
    A[0], B[0] = af[0], bf[0]
    for j in range(1, ptotal):
        A[j] = af[j] * A[j - 1]
        B[j] = af[j] * B[j - 1] + bf[j]
    np.testing.assert_allclose(np.asarray(ga).reshape(ptotal, n), A, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gb).reshape(ptotal, n), B, atol=1e-5)


@pytest.mark.parametrize("algo", ["sequential", "binomial_tree", "sklansky"])
def test_hierarchical_any_inner_outer_algorithm(algo):
    po, pi = 4, 4
    x = _stacked(po, pi, seed=9)
    got = sim_hierarchical_scan(
        x, "sum", po, pi, inner_algorithm=algo, outer_algorithm=algo
    )
    want = np.cumsum(np.asarray(x).reshape(po * pi, -1), axis=0)
    np.testing.assert_array_equal(
        np.asarray(got).reshape(po * pi, -1), want
    )


def test_hierarchical_spmd_2d_mesh(subprocess_runner):
    """dist_hierarchical_scan on a real 2x4 host-device mesh."""
    subprocess_runner("repro.testing.hierarchical_check", "2", "4")


def test_wrapper_equals_direct_planner_lowering():
    """The legacy 2D entry point is a thin wrapper: its result must equal a
    directly built + lowered 2-level plan, bit for bit."""
    from repro.offload import build_plan, lower_sim

    po, pi = 3, 4
    x = _stacked(po, pi, seed=21)
    via_wrapper = sim_hierarchical_scan(x, "sum", po, pi)
    plan = build_plan(
        "SCAN", (po, pi), "sum", 32, order=(0, 1),
        level_algorithms=("hillis_steele", "hillis_steele"),
    )
    via_plan = lower_sim(plan)(flat_equivalent(x, po, pi))
    np.testing.assert_array_equal(
        np.asarray(via_wrapper).reshape(po * pi, -1), np.asarray(via_plan)
    )
