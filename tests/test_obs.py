"""Observability tests: span-tree invariants from a traced dispatch,
traced-vs-jitted bitwise identity, Perfetto export round-trip (including
the empty span list) and host+device merge alignment plus its degrade
paths (missing/truncated/malformed device traces must record a reason,
never raise), the Prometheus exposition format and label escaping,
profiler fallback accounting, latency-histogram edge cases (including a
threaded stress test), broker request spans, and the obs_check CI
module. The health stack (flight recorder, SLOs, link attribution) is
covered by tests/test_health.py."""

import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.offload import OffloadEngine, build_plan, lower_sim, optimize_plan
from repro.service import DescriptorBroker, LatencyHistogram
from repro.service.telemetry import LATENCY_BUCKETS_US

AXES = (2, 4)
P = 8
N = 16


@pytest.fixture(autouse=True)
def _clean_obs():
    obs_tracing.set_tracer(None)
    obs_metrics.reset_registry()
    yield
    obs_tracing.set_tracer(None)
    obs_metrics.reset_registry()


def _x(seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-5, 6, size=(P, N)).astype(np.float32))


def _traced_scan_spans():
    eng = OffloadEngine()
    desc = eng.make_descriptor(
        "scan", axes=AXES, payload_bytes=N * 4, op="sum", optimize=True
    )
    x = _x()
    with obs_tracing.tracing() as tracer:
        out = eng.offload(desc, x)
    return eng, desc, x, np.asarray(out), tracer.spans()


# ------------------------------------------------------------ span tree


def test_traced_dispatch_span_tree_invariants():
    """engine.offload -> phase -> round, parents contain children, round
    spans per comm phase match the phase's own round count."""
    _, _, _, _, spans = _traced_scan_spans()
    by_id = {s.span_id: s for s in spans}
    roots = [s for s in spans if s.name == "engine.offload"]
    assert len(roots) == 1
    phases = [s for s in spans if s.cat == "phase"]
    rounds = [s for s in spans if s.cat == "round"]
    assert phases and rounds
    # every phase hangs off the engine span; every round off a phase
    for ph in phases:
        assert by_id[ph.parent_id].cat == "engine"
    for r in rounds:
        assert by_id[r.parent_id].cat == "phase"
    # containment: child window inside parent window
    for s in spans:
        parent = by_id.get(s.parent_id)
        if parent is not None:
            assert parent.start_us <= s.start_us
            assert s.end_us <= parent.end_us + 1e-3
    # comm phases declare their round count; the round spans must match
    comm = [ph for ph in phases if ph.args.get("rounds", 0) > 0]
    assert comm
    for ph in comm:
        children = [r for r in rounds if r.parent_id == ph.span_id]
        assert len(children) == ph.args["rounds"]
        # rounds are ordered and indexed from 0 within their phase
        assert [r.args["round"] for r in children] == list(
            range(len(children))
        )
        assert all(
            a.start_us <= b.start_us for a, b in zip(children, children[1:])
        )


def test_traced_result_bitwise_equals_jitted():
    """The traced eager interpreter must not change a single bit, and the
    jitted schedule must stay cached independently of the traced one."""
    eng, desc, x, traced_out, _ = _traced_scan_spans()
    baseline = np.asarray(eng.offload(desc, x))  # noop tracer -> jitted
    np.testing.assert_array_equal(traced_out, baseline)
    # both the jitted and the traced variant live in the schedule cache;
    # re-dispatching either is a cache hit
    before = eng.telemetry.snapshot()["misses"]
    np.testing.assert_array_equal(np.asarray(eng.offload(desc, x)), baseline)
    with obs_tracing.tracing():
        np.testing.assert_array_equal(
            np.asarray(eng.offload(desc, x)), baseline
        )
    assert eng.telemetry.snapshot()["misses"] == before


def test_noop_tracer_is_default_and_collects_nothing():
    tracer = obs_tracing.get_tracer()
    assert isinstance(tracer, obs_tracing.NoopTracer)
    assert not tracer.enabled
    with tracer.span("anything", "engine") as sp:
        sp.set(ignored=1)
    assert tracer.spans() == ()
    assert tracer.current_span_id() is None


def test_telemetry_snapshot_keys_unchanged_by_tracing():
    """The obs layer adds keys; it must not rename or drop existing ones."""
    eng, desc, x, _, _ = _traced_scan_spans()
    snap = eng.telemetry.snapshot()
    for key in (
        "hits", "misses", "hit_rate", "dispatches", "compiles", "errors",
        "cache_size", "cache_clears", "calls_by_coll", "mean_latency_us",
        "last_latency_us", "latency_by_coll_us",
        "device_latency_by_coll_us", "latency_source_by_coll",
    ):
        assert key in snap
    assert snap["profiler_fallbacks"] == 0
    assert snap["profiler_fallback_reasons"] == {}


def test_plan_level_tracing_via_lower_sim():
    """lower_sim(traced=True) emits spans without any engine involved."""
    plan = optimize_plan(
        build_plan("scan", AXES, "sum", N * 4, order=(0, 1))
    )
    fn = lower_sim(plan, traced=True)
    x = _x(1)
    with obs_tracing.tracing() as tracer:
        out = fn(x)
    want = np.asarray(jnp.asarray(lower_sim(plan)(x)))
    np.testing.assert_array_equal(np.asarray(out), want)
    cats = {s.cat for s in tracer.spans()}
    assert "phase" in cats and "round" in cats


def test_add_span_cross_thread_parent_links():
    """add_span records retroactive spans with explicit parents — the
    broker's queue-wait pattern — and keeps ordering by start time."""
    tracer = obs_tracing.Tracer()
    t0 = obs_tracing.now_us()
    root = tracer.add_span("service.submit", "service", t0, t0 + 5.0)
    child = tracer.add_span(
        "broker.queue_wait", "broker", t0 + 5.0, t0 + 9.0, parent_id=root
    )
    spans = tracer.spans()
    assert [s.span_id for s in spans] == [root, child]
    assert spans[1].parent_id == root
    assert spans[1].dur_us == pytest.approx(4.0)


# ------------------------------------------------------------ export


def test_chrome_round_trip_is_lossless():
    _, _, _, _, spans = _traced_scan_spans()
    trace = obs_export.spans_to_chrome(spans)
    # Perfetto/chrome essentials: metadata + complete events on the host pid
    assert any(e["ph"] == "M" for e in trace["traceEvents"])
    xs = [e for e in trace["traceEvents"] if e["ph"] == "X"]
    assert len(xs) == len(spans)
    assert all(e["pid"] == obs_export.HOST_PID for e in xs)
    back = obs_export.chrome_to_spans(trace)
    assert len(back) == len(spans)
    for a, b in zip(sorted(spans, key=lambda s: s.span_id),
                    sorted(back, key=lambda s: s.span_id)):
        assert (a.name, a.cat, a.span_id, a.parent_id) == (
            b.name, b.cat, b.span_id, b.parent_id
        )
        assert a.start_us == pytest.approx(b.start_us)
        assert a.dur_us == pytest.approx(b.dur_us)


def test_merge_device_trace_aligns_on_anchor():
    """A synthetic device trace sharing one event name with the host trace
    gets its clock shifted so the anchors coincide."""
    tracer = obs_tracing.Tracer()
    t0 = obs_tracing.now_us()
    tracer.add_span("repro_offload:scan:p8", "profile", t0, t0 + 100.0)
    host = obs_export.spans_to_chrome(tracer.spans())
    device = {
        "traceEvents": [
            {"ph": "X", "name": "repro_offload:scan:p8", "ts": 5000.0,
             "dur": 100.0, "pid": 9, "tid": 1},
            {"ph": "X", "name": "TfrtCpuExecutable::Execute", "ts": 5010.0,
             "dur": 42.0, "pid": 9, "tid": 1},
        ]
    }
    merged = obs_export.merge_device_trace(host, device)
    assert merged["deviceClockAligned"] is True
    assert merged["deviceEventsMerged"] >= 1
    dev = [
        e for e in merged["traceEvents"]
        if e.get("pid") == obs_export.DEVICE_PID and e.get("ph") == "X"
        and e["name"] != "repro_offload:scan:p8"
    ]
    assert dev
    # anchor was at ts=5000 on the device clock, t0 on the host clock:
    # the executable event 10us after the anchor lands 10us after t0
    assert dev[0]["ts"] == pytest.approx(t0 + 10.0)


def test_merge_without_common_event_keeps_device_clock():
    host = obs_export.spans_to_chrome(())
    device = {"traceEvents": [
        {"ph": "X", "name": "XlaModule:foo", "ts": 1.0, "dur": 2.0,
         "pid": 3, "tid": 4},
    ]}
    merged = obs_export.merge_device_trace(host, device)
    assert merged["deviceClockAligned"] is False
    assert merged["deviceEventsMerged"] == 1


def test_chrome_round_trip_empty_span_list():
    """Zero spans is a valid trace: metadata only out, zero spans back."""
    trace = obs_export.spans_to_chrome(())
    assert all(e["ph"] == "M" for e in trace["traceEvents"])
    assert obs_export.chrome_to_spans(trace) == []


def test_merge_missing_device_trace_degrades(tmp_path):
    """A nonexistent device-trace path must not raise: the merged result
    is the host trace with the failure reason recorded, and the degrade
    lands in the flight recorder as a profiler_fallback event."""
    from repro.obs import events as obs_events

    rec = obs_events.FlightRecorder()
    prev = obs_events.set_recorder(rec)
    try:
        host = obs_export.spans_to_chrome(())
        merged = obs_export.merge_device_trace(
            host, tmp_path / "never_written.json.gz"
        )
    finally:
        obs_events.set_recorder(prev)
    assert merged["deviceEventsMerged"] == 0
    assert merged["deviceClockAligned"] is False
    assert "unreadable" in merged["deviceMergeError"]
    assert len(host["traceEvents"]) == len(merged["traceEvents"])
    falls = rec.events(kind="profiler_fallback")
    assert falls and falls[0]["reason"] == "merge_unreadable_trace"


def test_merge_unparseable_device_trace_degrades(tmp_path):
    """Truncated JSON (the profiler died mid-write) degrades with a
    recorded reason instead of taking down the host-trace export."""
    bad = tmp_path / "truncated.json"
    bad.write_text('{"traceEvents": [{"ph": "X", "name": "XlaModule')
    host = obs_export.spans_to_chrome(())
    merged = obs_export.merge_device_trace(host, bad)
    assert merged["deviceEventsMerged"] == 0
    assert "unreadable" in merged["deviceMergeError"]


def test_merge_non_object_device_trace_degrades(tmp_path):
    """Valid JSON of the wrong shape (a list) is malformed, not a crash."""
    bad = tmp_path / "list.json"
    bad.write_text('[{"ph": "X"}]')
    merged = obs_export.merge_device_trace(
        obs_export.spans_to_chrome(()), bad
    )
    assert merged["deviceEventsMerged"] == 0
    assert "malformed" in merged["deviceMergeError"]
    assert "list" in merged["deviceMergeError"]


def test_write_trace_and_load(tmp_path):
    _, _, _, _, spans = _traced_scan_spans()
    out = tmp_path / "trace.json"
    obs_export.write_trace(out, obs_export.spans_to_chrome(spans))
    loaded = obs_export.load_chrome_trace(out)
    assert len(loaded["traceEvents"]) >= len(spans)


# ------------------------------------------------------------ metrics


def test_prometheus_exposition_format():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("repro_test_total", "a counter", labelnames=("coll",))
    c.inc(coll="scan")
    c.inc(2, coll="scan")
    g = reg.gauge("repro_test_depth", "a gauge")
    g.set(3.5)
    h = reg.histogram(
        "repro_test_us", "a histogram", buckets=(1.0, 10.0)
    )
    h.observe(0.5)
    h.observe(5.0)
    h.observe(100.0)
    text = reg.render()
    assert "# HELP repro_test_total a counter" in text
    assert "# TYPE repro_test_total counter" in text
    assert 'repro_test_total{coll="scan"} 3' in text
    assert "repro_test_depth 3.5" in text
    # cumulative buckets + the +Inf catch-all, sum and count
    assert 'repro_test_us_bucket{le="1"} 1' in text
    assert 'repro_test_us_bucket{le="10"} 2' in text
    assert 'repro_test_us_bucket{le="+Inf"} 3' in text
    assert "repro_test_us_sum 105.5" in text
    assert "repro_test_us_count 3" in text


def test_prometheus_label_escaping():
    """Backslash, quote, and newline in a label value must arrive escaped
    per the exposition format — a tenant named "a\\b" or containing a
    newline must not corrupt the scrape."""
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("repro_esc_total", "escapes", labelnames=("tenant",))
    c.inc(tenant='quo"te')
    c.inc(tenant="back\\slash")
    c.inc(tenant="new\nline")
    text = reg.render()
    assert 'repro_esc_total{tenant="quo\\"te"} 1' in text
    assert 'repro_esc_total{tenant="back\\\\slash"} 1' in text
    assert 'repro_esc_total{tenant="new\\nline"} 1' in text
    assert "\nline" not in text.replace("\\n", "")  # no raw newline leaked


def test_registry_get_or_create_conflicts():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("repro_x_total", "x")
    assert reg.counter("repro_x_total", "x") is c
    with pytest.raises(ValueError):
        reg.gauge("repro_x_total", "x")
    with pytest.raises(ValueError):
        reg.counter("repro_x_total", "x", labelnames=("coll",))
    with pytest.raises(ValueError):
        c.inc(-1.0)


def test_round_bucket_labels():
    assert obs_metrics.round_bucket(0) == "0"
    assert obs_metrics.round_bucket(3) == "3"
    assert obs_metrics.round_bucket(4) == "4-7"
    assert obs_metrics.round_bucket(9) == "8-15"
    assert obs_metrics.round_bucket(100) == "64-127"


def test_dispatch_publishes_engine_metrics():
    eng = OffloadEngine()
    desc = eng.make_descriptor(
        "scan", axes=AXES, payload_bytes=N * 4, op="sum", optimize=True
    )
    eng.offload(desc, _x())
    text = obs_metrics.render_prometheus()
    assert 'repro_engine_dispatches_total{coll="scan"} 1' in text
    assert "repro_engine_dispatch_latency_us_bucket" in text
    assert 'repro_engine_cache_events_total{event="miss"} 1' in text
    with obs_tracing.tracing():
        eng.offload(desc, _x())
    text = obs_metrics.render_prometheus()
    # the traced dispatch observed per-round and per-phase histograms
    assert "repro_round_latency_us_bucket" in text
    assert 'phase_kind="SCAN"' in text


# ------------------------------------------------------------ profiling


def test_profiler_fallback_reason_is_counted(monkeypatch):
    """A profiler that cannot start degrades to wall source AND surfaces
    the reason in telemetry + metrics instead of failing silently."""
    import jax

    eng = OffloadEngine()
    desc = eng.make_descriptor(
        "scan", axes=AXES, payload_bytes=N * 4, op="sum", optimize=True
    )

    def boom(*a, **k):
        raise RuntimeError("another profiler session is active")

    monkeypatch.setattr(jax.profiler, "start_trace", boom)
    t = eng.profile_offload(desc, _x())
    assert t.source == "wall"
    assert t.fallback_reason == "trace_start_failed"
    snap = eng.telemetry.snapshot()
    assert snap["profiler_fallbacks"] == 1
    assert snap["profiler_fallback_reasons"] == {"trace_start_failed": 1}
    assert (
        'repro_engine_profiler_fallbacks_total'
        '{coll="scan",reason="trace_start_failed"} 1'
    ) in obs_metrics.render_prometheus()


# ------------------------------------------------------ latency histogram


def test_latency_histogram_edge_cases():
    h = LatencyHistogram()
    # empty: every quantile is 0, not a bucket edge
    assert h.percentile_us(0.0) == 0.0
    assert h.percentile_us(0.5) == 0.0
    assert h.percentile_us(1.0) == 0.0
    with pytest.raises(ValueError):
        h.percentile_us(1.5)
    with pytest.raises(ValueError):
        h.percentile_us(-0.1)
    # single sample: all quantiles collapse to it (not to the 50us edge)
    h.record(10e-6)
    for q in (0.0, 0.5, 0.99, 1.0):
        assert h.percentile_us(q) == pytest.approx(10.0)
    assert h.min_us == pytest.approx(10.0)
    assert h.max_us == pytest.approx(10.0)
    # open-bucket sample reports the observed max, not infinity
    h2 = LatencyHistogram()
    big = (LATENCY_BUCKETS_US[-1] * 3) * 1e-6
    h2.record(big)
    assert h2.percentile_us(0.99) == pytest.approx(big * 1e6)
    # percentiles never leave [min, max]
    h3 = LatencyHistogram()
    h3.record(60e-6)
    h3.record(70e-6)  # both in the (50, 100] bucket
    assert h3.percentile_us(0.5) == pytest.approx(70.0)
    assert h3.percentile_us(0.0) == pytest.approx(60.0)


def test_latency_histogram_threaded_stress():
    """Concurrent recorders + snapshot readers: totals conserve and no
    reader ever observes torn state."""
    h = LatencyHistogram()
    n_threads, per_thread = 8, 500
    errors = []

    def writer(seed):
        rng = np.random.default_rng(seed)
        for _ in range(per_thread):
            h.record(float(rng.uniform(1e-6, 2e-1)))

    def reader():
        for _ in range(200):
            snap = h.snapshot()
            if snap["count"]:
                lo, hi = snap["min_us"], snap["max_us"]
                mean, p50 = snap["mean_us"], snap["p50_us"]
                if not (lo <= mean <= hi and lo <= p50 <= hi):
                    errors.append(snap)

    threads = [
        threading.Thread(target=writer, args=(i,)) for i in range(n_threads)
    ] + [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert h.count == n_threads * per_thread
    assert sum(h.counts) == h.count
    assert h.min_us <= h.percentile_us(0.5) <= h.max_us


# ------------------------------------------------------------ broker


def test_broker_request_spans_link_submit_to_dispatch():
    """service.submit -> broker.queue_wait -> broker.dispatch_group ->
    engine.offload, linked by explicit parent ids across threads."""
    with obs_tracing.tracing() as tracer:
        broker = DescriptorBroker(OffloadEngine())
        desc = broker.make_descriptor(
            "SCAN", p=P, payload_bytes=N * 4, op="sum"
        )
        ticket = broker.client("t0").submit(desc.encode(), _x())
        assert broker.drain() == 1
        ticket.result(5)
    spans = tracer.spans()
    by_name = {}
    for s in spans:
        by_name.setdefault(s.name, s)
    submit = by_name.get("service.submit")
    wait = by_name.get("broker.queue_wait")
    group = by_name.get("broker.dispatch_group")
    assert submit is not None and wait is not None and group is not None
    assert wait.parent_id == submit.span_id
    assert submit.args["tenant"] == "t0"
    assert submit.args["coll"] == "scan"
    # the engine span belongs to the dispatch-group window
    engine = [s for s in spans if s.name == "engine.offload"]
    assert engine and engine[0].parent_id == group.span_id


# ------------------------------------------------------------ CI module


def test_obs_check_module(subprocess_runner):
    out = subprocess_runner("repro.testing.obs_check", "2", "2")
    assert "obs_check_summary,bitwise_equal,1," in out
