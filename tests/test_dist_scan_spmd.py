"""Real-ppermute validation of the offloaded scan (8/16 forced host devices,
fresh subprocess because jax locks the device count at first init)."""

import pytest


@pytest.mark.parametrize("ndev", [8, 16])
def test_spmd_all_algorithms(subprocess_runner, ndev):
    out = subprocess_runner("repro.testing.spmd_check", str(ndev))
    assert "FAIL" not in out
