"""Lowering-backend registry tests: the contract, soft capability
fallback (with engine telemetry), cache-key stability for the mode
defaults, the tuner's backend column / measured backend winners feeding
``make_descriptor(backend="auto")``, the folded-in hierarchical entry
points, and the fused-Pallas-kernel bitwise gate vs ``lower_spmd``
(subprocess, multi-device)."""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import SSD, SUM
from repro.core.operators import get_operator
from repro.core.selector import set_active_tuning
from repro.kernels import pallas_collective
from repro.offload import OffloadEngine, TuningCache, backends
from repro.offload.passes import choose_backend
from repro.offload.planner import build_plan, lower_sim

P = 8
N = 16


@pytest.fixture(autouse=True)
def _no_active_tuning():
    set_active_tuning(None)
    yield
    set_active_tuning(None)


def _payload(seed=0, p=P):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-5, 6, size=(p, N)).astype(np.float32))


# ------------------------------------------------------------------ registry


def test_registry_names_and_fingerprints():
    assert backends.backend_names() == ("pallas", "sim", "spmd")
    # the mode defaults MUST contribute no cache-key fields (key stability)
    assert backends.get_backend("sim").fingerprint() == ()
    assert backends.get_backend("spmd").fingerprint() == ()
    assert backends.get_backend("pallas").fingerprint() == (
        ("backend", "pallas"),
    )
    assert backends.default_backend_name(None) == "sim"
    assert backends.default_backend_name(("i",)) == "spmd"


def test_unknown_and_default_backend_names_raise():
    with pytest.raises(ValueError, match="unknown lowering backend"):
        backends.get_backend("netfpga")
    # "" is mode-dependent; only resolve() may map it
    with pytest.raises(ValueError, match="mode-dependent"):
        backends.get_backend("")


def test_resolve_soft_fallback_reasons():
    single = build_plan("SCAN", (P,), SUM, 4 * N)
    multi = build_plan("SCAN", (2, 4), SUM, 4 * N)

    # in-capability request resolves to the named backend, no reason
    b, reason = backends.resolve("pallas", single)
    assert b.name == "pallas" and reason == ""

    # default name resolves to the mode default, never counted
    b, reason = backends.resolve("", single)
    assert b.name == "sim" and reason == ""
    b, reason = backends.resolve("", multi, ("a", "b"))
    assert b.name == "spmd" and reason == ""

    # capability misses fall back with the stable telemetry token
    b, reason = backends.resolve("pallas", multi, ("a", "b"))
    assert b.name == "spmd" and reason == "multi_axis_mesh"
    b, reason = backends.resolve("pallas", multi)
    assert b.name == "sim" and reason == "not_single_axis"
    chunked = dataclasses.replace(single, chunking=4)
    b, reason = backends.resolve("pallas", chunked)
    assert b.name == "sim" and reason == "chunked"

    # a typo is a bug, not a capability miss
    with pytest.raises(ValueError, match="unknown lowering backend"):
        backends.resolve("netfpga", single)


@pytest.mark.parametrize("opname", ["max", "ssd"])
def test_non_zero_identity_ops_rejected(opname):
    """The kernel's zero-fill recv IS its identity handling, so operators
    whose identity is not all-zeros are outside the capability envelope."""
    op = SSD if opname == "ssd" else get_operator(opname)
    plan = build_plan("SCAN", (P,), op, 4 * N)
    ok, reason = pallas_collective.supports_plan(plan, ("i",))
    assert not ok and reason == "op_flags"
    b, reason = backends.resolve("pallas", plan)
    assert b.name == "sim" and reason == "op_flags"


# ------------------------------------------------------- sim-form bitwise


@pytest.mark.parametrize("coll", ["SCAN", "EXSCAN"])
def test_sim_form_bitwise_vs_lower_sim(coll):
    """The fused kernel's stacked-input form matches the op-per-round sim
    lowering bit for bit (interpret mode, no mesh)."""
    plan = build_plan(coll, (P,), SUM, 4 * N)
    x = _payload()
    ref = lower_sim(plan, SUM)(x)
    got = backends.get_backend("pallas").lower(plan, SUM)(x)
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------- engine dispatch + cache


def test_engine_pinned_pallas_bitwise_distinct_cache_entry():
    eng = OffloadEngine()
    x = _payload()
    default = eng.make_descriptor(
        "SCAN", axes=(1, P), payload_bytes=4 * N, backend=""
    )
    pinned = eng.make_descriptor(
        "SCAN", axes=(1, P), payload_bytes=4 * N, backend="pallas"
    )
    ref = np.asarray(eng.offload(default, x))
    got = np.asarray(eng.offload(pinned, x))
    np.testing.assert_array_equal(ref, got)
    # the pallas fingerprint gives the fused schedule its own cache row;
    # no fallback was taken (the plan is in-capability)
    assert eng.cache_size() == 2
    t = eng.telemetry.snapshot()
    assert t["backend_fallbacks"] == 0
    assert t["backend_fallback_reasons"] == {}


def test_engine_fallback_shares_cache_entry_and_counts_once():
    eng = OffloadEngine()
    x = _payload()
    default = eng.make_descriptor(
        "SCAN", axes=(2, 4), payload_bytes=4 * N, backend=""
    )
    pinned = eng.make_descriptor(
        "SCAN", axes=(2, 4), payload_bytes=4 * N, backend="pallas"
    )
    ref = np.asarray(eng.offload(default, x))
    got = np.asarray(eng.offload(pinned, x))
    np.testing.assert_array_equal(ref, got)
    # the fallen-back dispatch resolves to the default lowering with the
    # default (empty) fingerprint -> it reuses the default's cache entry
    assert eng.cache_size() == 1
    t = eng.telemetry.snapshot()
    assert t["backend_fallbacks"] == 1
    assert t["backend_fallback_reasons"] == {"not_single_axis": 1}
    # repeat dispatch: memoized resolution, no double counting
    np.asarray(eng.offload(pinned, x))
    assert eng.telemetry.snapshot()["backend_fallbacks"] == 1


def test_default_backend_cache_key_is_stable():
    """A descriptor that doesn't name a backend produces the same single
    cache entry whether built before or after the registry existed — the
    default's empty fingerprint adds no key fields."""
    eng = OffloadEngine()
    x = _payload()
    auto = eng.make_descriptor("SCAN", axes=(1, P), payload_bytes=4 * N)
    assert auto.backend == ""  # untuned "auto" resolves to the default
    eng.offload(auto, x)
    keys_before = set(eng._cache)
    explicit = eng.make_descriptor(
        "SCAN", axes=(1, P), payload_bytes=4 * N, backend=""
    )
    eng.offload(explicit, x)
    assert set(eng._cache) == keys_before
    assert eng.cache_size() == 1


# ------------------------------------------------ tuning: backend winners


def _cache_with_race(default_s, pallas_s, payload=1024):
    cache = TuningCache(backend="test")
    cache.record_schedule(
        "scan", (1, P), True, 1, payload, default_s, backend=""
    )
    cache.record_schedule(
        "scan", (1, P), True, 1, payload, pallas_s, backend="pallas"
    )
    return cache


def test_backend_winners_reduce_and_tie_toward_default():
    cache = _cache_with_race(2e-5, 1e-5)
    assert cache.backend_winners == {("scan", (1, P), 1024): "pallas"}
    # nearest-payload lookup, exact sizes only
    assert cache.backend_winner("scan", (1, P), 2048) == "pallas"
    assert cache.backend_winner("scan", (2, 4), 1024) is None
    # ties break toward "" (the reference semantics)
    tied = _cache_with_race(1e-5, 1e-5)
    assert tied.backend_winners == {("scan", (1, P), 1024): ""}
    # a grid point with only default rows never steers backend="auto"
    solo = TuningCache(backend="test")
    solo.record_schedule("scan", (1, P), True, 1, 1024, 1e-5, backend="")
    assert solo.backend_winners == {}
    assert solo.backend_winner("scan", (1, P), 1024) is None


def test_schedule_winners_ignore_non_default_backend_rows():
    """The (optimized, chunks) schedule winner compares like with like:
    only default-backend rows compete, however fast the pallas row was."""
    cache = TuningCache(backend="test")
    cache.record_schedule("scan", (1, P), False, 1, 1024, 3e-5, backend="")
    cache.record_schedule("scan", (1, P), True, 1, 1024, 2e-5, backend="")
    cache.record_schedule(
        "scan", (1, P), False, 1, 1024, 1e-6, backend="pallas"
    )
    assert cache.schedule_winners[("scan", (1, P), 1024)] == (True, 1)


def test_backend_column_json_round_trip(tmp_path):
    import json

    cache = _cache_with_race(2e-5, 1e-5)
    back = TuningCache.load(cache.save(tmp_path / "tt.json"))
    assert sorted(m.backend for m in back.fusion_measurements) == [
        "", "pallas",
    ]
    assert back.backend_winners == cache.backend_winners
    # rows from tables written before the backend column default to ""
    d = cache.to_json()
    for row in d["fusion_measurements"]:
        row.pop("backend", None)
    legacy_path = tmp_path / "legacy.json"
    legacy_path.write_text(json.dumps(d))
    legacy = TuningCache.load(legacy_path)
    assert all(m.backend == "" for m in legacy.fusion_measurements)
    assert legacy.backend_winners == {}


def test_choose_backend_and_descriptor_auto_resolution():
    # untuned: the mode default, never speculative
    assert choose_backend("scan", (1, P), 1024) == ""
    eng = OffloadEngine()
    desc = eng.make_descriptor("SCAN", axes=(1, P), payload_bytes=1024)
    assert desc.backend == ""

    set_active_tuning(_cache_with_race(2e-5, 1e-5))
    assert choose_backend("scan", (1, P), 1024) == "pallas"
    assert choose_backend("scan", (2, 4), 1024) == ""  # no race recorded
    tuned = eng.make_descriptor("SCAN", axes=(1, P), payload_bytes=1024)
    assert tuned.backend == "pallas"
    # the winner travels on the wire and still dispatches bitwise-equal
    # (capability-checked at compile time like any pinned backend)
    x = _payload()
    got = np.asarray(eng.offload(tuned.encode(), x))
    set_active_tuning(None)
    ref = np.asarray(eng.offload(desc, x))
    np.testing.assert_array_equal(ref, got)


# --------------------------------------- hierarchical entry points folded in


def test_hierarchical_module_folded_into_backends():
    with pytest.raises(ModuleNotFoundError):
        import repro.offload.hierarchical  # noqa: F401


def test_sim_hierarchical_scan_matches_flat_reference():
    rng = np.random.default_rng(3)
    stacked = jnp.asarray(
        rng.integers(-5, 6, size=(2, 4, N)).astype(np.float32)
    )
    out = backends.sim_hierarchical_scan(stacked, "sum", 2, 4)
    want = np.cumsum(
        np.asarray(stacked).reshape(8, N), axis=0
    ).reshape(2, 4, N)
    np.testing.assert_array_equal(np.asarray(out), want)


# --------------------------------------------- spmd bitwise gate (subprocess)


def test_pallas_check_spmd_bitwise(subprocess_runner):
    """lower_pallas == lower_spmd bit-for-bit on a 1x8 host mesh:
    SCAN/EXSCAN (sum), BARRIER, both FUSED_SCAN_TOTAL forms, plus the
    op_flags capability rejections."""
    out = subprocess_runner("repro.testing.pallas_check", str(P))
    assert f"pallas_check,scan:sum,p,{P},bitwise,1" in out
    assert f"pallas_check,barrier,p,{P},bitwise,1" in out
