"""Deliverable gates: dry-run artifact completeness + report generation.

Skipped when artifacts haven't been generated (fresh clone); on this repo
they exist and the gates are enforced: every applicable (arch x shape) cell
must have a single-pod AND multi-pod artifact, with sane contents.
"""

import json
from pathlib import Path

import pytest

from repro.configs import ARCH_IDS, applicable_shapes, get_config

ART = Path(__file__).resolve().parents[1] / "benchmarks" / "artifacts" / "dryrun"


def _cells():
    for arch in ARCH_IDS:
        for shape in applicable_shapes(get_config(arch)):
            yield arch, shape


@pytest.mark.skipif(not ART.exists(), reason="dry-run artifacts not generated")
@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_all_cells_have_artifacts(mesh):
    missing = []
    for arch, shape in _cells():
        p = ART / f"{arch}__{shape}__{mesh}.json"
        if not p.exists():
            missing.append((arch, shape))
    assert not missing, f"missing {mesh} dry-run cells: {missing}"


@pytest.mark.skipif(not ART.exists(), reason="dry-run artifacts not generated")
def test_artifact_contents_sane():
    n = 0
    for arch, shape in _cells():
        p = ART / f"{arch}__{shape}__single.json"
        if not p.exists():
            continue
        r = json.loads(p.read_text())
        ro = r["roofline"]
        assert r["n_chips"] == 256
        assert ro["flops_per_device"] > 0, (arch, shape)
        assert ro["bytes_per_device"] > 0, (arch, shape)
        assert ro["bottleneck"] in ("compute", "memory", "collective")
        # multi-pod shards batch further: args/device must not grow
        pm = ART / f"{arch}__{shape}__multi.json"
        if pm.exists():
            rm = json.loads(pm.read_text())
            assert rm["n_chips"] == 512
        n += 1
    assert n >= 30


@pytest.mark.skipif(not ART.exists(), reason="dry-run artifacts not generated")
def test_report_generates():
    from benchmarks.report import dryrun_table, roofline_table

    t = roofline_table("single")
    assert t.count("\n") >= 30
    assert "bottleneck" in t
    d = dryrun_table("multi")
    assert "512" in d


def test_long_500k_only_subquadratic():
    runs_long = {
        a for a in ARCH_IDS
        if "long_500k" in applicable_shapes(get_config(a))
    }
    assert runs_long == {"mamba2_130m", "jamba_v01_52b", "gemma3_27b"}
