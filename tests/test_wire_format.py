"""Descriptor wire-format compatibility tests: the 10-word legacy layout,
the 15-word topology layout for every 1-3-axis split, the 16-word
optimizer-flag layout, and malformed-length rejection. The wire words are
the service's request format — every broker submission round-trips through
them — so the layout is a compatibility contract, not an implementation
detail."""

import itertools

import numpy as np
import pytest

from repro.core import CollType, CollectiveDescriptor
from repro.core.packet import (
    _CHUNK_WORDS,
    _LEGACY_WORDS,
    _OPT_WORDS,
    _TOPO_WORDS,
    MAX_AXES,
    MsgType,
    WireDType,
    WireOp,
    split_from_index,
    split_index,
)

assert (
    _LEGACY_WORDS == 10 and _TOPO_WORDS == 15 and _OPT_WORDS == 16
    and _CHUNK_WORDS == 17
), "wire layout changed"


def _legacy_words(**over):
    fields = dict(
        comm_id=7, comm_size=8, coll_type=int(CollType.EXSCAN), algo_type=4,
        rank=3, root=5, operation=int(WireOp.MAX),
        data_type=int(WireDType.BFLOAT16), count=33,
        msg_type=int(MsgType.PARTIAL),
    )
    fields.update(over)
    return np.asarray(list(fields.values()), dtype=np.uint32)


def test_legacy_10_word_decode_round_trips():
    """A pre-topology 10-word request decodes to a single-axis descriptor,
    and its re-encode (16 words, zeroed topology + flag tail) decodes to
    the same one."""
    words = _legacy_words()
    desc = CollectiveDescriptor.decode(words)
    assert desc.comm_id == 7 and desc.comm_size == 8
    assert desc.coll_type == CollType.EXSCAN
    assert desc.algo_type == "binomial_tree"
    assert desc.rank == 3 and desc.root == 5
    assert desc.operation == WireOp.MAX
    assert desc.data_type == WireDType.BFLOAT16
    assert desc.count == 33 and desc.msg_type == MsgType.PARTIAL
    assert desc.axes == () and desc.split == ()
    assert desc.optimized is False
    re = desc.encode()
    assert re.shape == (_OPT_WORDS,) and re.dtype == np.uint32
    # legacy prefix preserved verbatim; topology + flag tail zeroed
    np.testing.assert_array_equal(re[:_LEGACY_WORDS], words)
    np.testing.assert_array_equal(re[_LEGACY_WORDS:], np.zeros(6, np.uint32))
    assert CollectiveDescriptor.decode(re) == desc


@pytest.mark.parametrize("n_axes", [1, 2, 3])
@pytest.mark.parametrize("optimized", [False, True])
def test_topology_encode_decode_all_splits(n_axes, optimized):
    """16-word round-trip for every axis count, split permutation, and
    optimizer-flag setting; the 15-word prefix still decodes (flag off)."""
    sizes_by_n = {1: (8,), 2: (2, 4), 3: (2, 2, 2)}
    sizes = sizes_by_n[n_axes]
    for order in itertools.permutations(range(n_axes)):
        desc = CollectiveDescriptor(
            comm_size=int(np.prod(sizes)),
            coll_type=CollType.ALLREDUCE,
            algo_type="hillis_steele",
            count=64,
            axes=sizes,
            split=order,
            optimized=optimized,
        )
        words = desc.encode()
        assert words.shape == (_OPT_WORDS,)
        assert words[_LEGACY_WORDS] == n_axes
        np.testing.assert_array_equal(
            words[_LEGACY_WORDS + 1 : _LEGACY_WORDS + 1 + MAX_AXES],
            np.asarray(
                list(sizes) + [0] * (MAX_AXES - n_axes), np.uint32
            ),
        )
        assert words[_TOPO_WORDS - 1] == split_index(order)
        assert words[-1] == int(optimized)
        back = CollectiveDescriptor.decode(words)
        assert back == desc
        assert back.axes == sizes and back.split == order
        assert back.optimized is optimized
        # the 15-word (pre-optimizer) prefix keeps decoding, flag off
        legacy_topo = CollectiveDescriptor.decode(words[:_TOPO_WORDS])
        assert legacy_topo.axes == sizes and legacy_topo.split == order
        assert legacy_topo.optimized is False


def test_optimized_flag_requires_topology():
    with pytest.raises(ValueError, match="multi-axis"):
        CollectiveDescriptor(comm_size=8, optimized=True)
    # and the flag survives normalization (it shapes the schedule, so the
    # engine cache key and the broker group key must both see it)
    desc = CollectiveDescriptor(
        comm_size=8, axes=(2, 4), count=4, optimized=True, rank=3,
        msg_type=MsgType.PARTIAL,
    )
    norm = desc.normalized()
    assert norm.optimized is True and norm.rank == 0


def test_split_index_is_lexicographic_and_invertible():
    for n in (1, 2, 3):
        perms = list(itertools.permutations(range(n)))
        for i, perm in enumerate(perms):
            assert split_index(perm) == i
            assert split_from_index(i, n) == perm
    with pytest.raises(ValueError, match="not a permutation"):
        split_index((0, 0))
    with pytest.raises(ValueError, match="out of range"):
        split_from_index(6, 3)


@pytest.mark.parametrize("length", [0, 1, 9, 11, 14, 18, 32])
def test_malformed_length_rejected_with_clear_error(length):
    words = np.ones(length, dtype=np.uint32)
    with pytest.raises(ValueError) as exc:
        CollectiveDescriptor.decode(words)
    msg = str(exc.value)
    # the error must name all accepted lengths and the offending one
    # (delimited match: "1" in "10" must not satisfy the length=1 case)
    assert str(_LEGACY_WORDS) in msg and str(_TOPO_WORDS) in msg
    assert str(_OPT_WORDS) in msg and str(_CHUNK_WORDS) in msg
    assert f"got {length}" in msg


def test_topology_words_internally_consistent_on_decode():
    """A topology word vector whose sizes don't factor comm_size is rejected
    by the descriptor invariant, not silently accepted."""
    desc = CollectiveDescriptor(
        comm_size=8, axes=(2, 4), count=4, coll_type=CollType.SCAN
    )
    words = desc.encode().copy()
    words[1] = 9  # comm_size no longer equals prod(axes)
    with pytest.raises(ValueError, match="factor"):
        CollectiveDescriptor.decode(words)
