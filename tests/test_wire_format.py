"""Descriptor wire-format compatibility tests: the 10-word legacy layout,
the 15-word topology layout for every 1-3-axis split, the 16-word
schedule-flags layout (optimizer bit + lowering-backend id), the 17-word
chunked layout, and malformed-length rejection. The wire words are
the service's request format — every broker submission round-trips through
them — so the layout is a compatibility contract, not an implementation
detail."""

import itertools

import numpy as np
import pytest

from repro.core import CollType, CollectiveDescriptor
from repro.core.packet import (
    _CHUNK_WORDS,
    _LEGACY_WORDS,
    _OPT_WORDS,
    _TOPO_WORDS,
    _WIRE_BACKENDS,
    MAX_AXES,
    MsgType,
    WireDType,
    WireOp,
    split_from_index,
    split_index,
)

assert (
    _LEGACY_WORDS == 10 and _TOPO_WORDS == 15 and _OPT_WORDS == 16
    and _CHUNK_WORDS == 17
), "wire layout changed"


def _legacy_words(**over):
    fields = dict(
        comm_id=7, comm_size=8, coll_type=int(CollType.EXSCAN), algo_type=4,
        rank=3, root=5, operation=int(WireOp.MAX),
        data_type=int(WireDType.BFLOAT16), count=33,
        msg_type=int(MsgType.PARTIAL),
    )
    fields.update(over)
    return np.asarray(list(fields.values()), dtype=np.uint32)


def test_legacy_10_word_decode_round_trips():
    """A pre-topology 10-word request decodes to a single-axis descriptor,
    and its re-encode (16 words, zeroed topology + flag tail) decodes to
    the same one."""
    words = _legacy_words()
    desc = CollectiveDescriptor.decode(words)
    assert desc.comm_id == 7 and desc.comm_size == 8
    assert desc.coll_type == CollType.EXSCAN
    assert desc.algo_type == "binomial_tree"
    assert desc.rank == 3 and desc.root == 5
    assert desc.operation == WireOp.MAX
    assert desc.data_type == WireDType.BFLOAT16
    assert desc.count == 33 and desc.msg_type == MsgType.PARTIAL
    assert desc.axes == () and desc.split == ()
    assert desc.optimized is False
    re = desc.encode()
    assert re.shape == (_OPT_WORDS,) and re.dtype == np.uint32
    # legacy prefix preserved verbatim; topology + flag tail zeroed
    np.testing.assert_array_equal(re[:_LEGACY_WORDS], words)
    np.testing.assert_array_equal(re[_LEGACY_WORDS:], np.zeros(6, np.uint32))
    assert CollectiveDescriptor.decode(re) == desc


@pytest.mark.parametrize("n_axes", [1, 2, 3])
@pytest.mark.parametrize("optimized", [False, True])
def test_topology_encode_decode_all_splits(n_axes, optimized):
    """16-word round-trip for every axis count, split permutation, and
    optimizer-flag setting; the 15-word prefix still decodes (flag off)."""
    sizes_by_n = {1: (8,), 2: (2, 4), 3: (2, 2, 2)}
    sizes = sizes_by_n[n_axes]
    for order in itertools.permutations(range(n_axes)):
        desc = CollectiveDescriptor(
            comm_size=int(np.prod(sizes)),
            coll_type=CollType.ALLREDUCE,
            algo_type="hillis_steele",
            count=64,
            axes=sizes,
            split=order,
            optimized=optimized,
        )
        words = desc.encode()
        assert words.shape == (_OPT_WORDS,)
        assert words[_LEGACY_WORDS] == n_axes
        np.testing.assert_array_equal(
            words[_LEGACY_WORDS + 1 : _LEGACY_WORDS + 1 + MAX_AXES],
            np.asarray(
                list(sizes) + [0] * (MAX_AXES - n_axes), np.uint32
            ),
        )
        assert words[_TOPO_WORDS - 1] == split_index(order)
        assert words[-1] == int(optimized)
        back = CollectiveDescriptor.decode(words)
        assert back == desc
        assert back.axes == sizes and back.split == order
        assert back.optimized is optimized
        # the 15-word (pre-optimizer) prefix keeps decoding, flag off
        legacy_topo = CollectiveDescriptor.decode(words[:_TOPO_WORDS])
        assert legacy_topo.axes == sizes and legacy_topo.split == order
        assert legacy_topo.optimized is False


def test_optimized_flag_requires_topology():
    with pytest.raises(ValueError, match="multi-axis"):
        CollectiveDescriptor(comm_size=8, optimized=True)
    # and the flag survives normalization (it shapes the schedule, so the
    # engine cache key and the broker group key must both see it)
    desc = CollectiveDescriptor(
        comm_size=8, axes=(2, 4), count=4, optimized=True, rank=3,
        msg_type=MsgType.PARTIAL,
    )
    norm = desc.normalized()
    assert norm.optimized is True and norm.rank == 0


def test_split_index_is_lexicographic_and_invertible():
    for n in (1, 2, 3):
        perms = list(itertools.permutations(range(n)))
        for i, perm in enumerate(perms):
            assert split_index(perm) == i
            assert split_from_index(i, n) == perm
    with pytest.raises(ValueError, match="not a permutation"):
        split_index((0, 0))
    with pytest.raises(ValueError, match="out of range"):
        split_from_index(6, 3)


@pytest.mark.parametrize("length", [0, 1, 9, 11, 14, 18, 32])
def test_malformed_length_rejected_with_clear_error(length):
    words = np.ones(length, dtype=np.uint32)
    with pytest.raises(ValueError) as exc:
        CollectiveDescriptor.decode(words)
    msg = str(exc.value)
    # the error must name all accepted lengths and the offending one
    # (delimited match: "1" in "10" must not satisfy the length=1 case)
    assert str(_LEGACY_WORDS) in msg and str(_TOPO_WORDS) in msg
    assert str(_OPT_WORDS) in msg and str(_CHUNK_WORDS) in msg
    assert f"got {length}" in msg


def _planned_desc(**over):
    fields = dict(
        comm_size=8, coll_type=CollType.SCAN, algo_type="hillis_steele",
        count=16, axes=(2, 4), split=(0, 1),
    )
    fields.update(over)
    return CollectiveDescriptor(**fields)


@pytest.mark.parametrize(
    "length", [_LEGACY_WORDS, _TOPO_WORDS, _OPT_WORDS, _CHUNK_WORDS]
)
@pytest.mark.parametrize("optimized", [False, True])
@pytest.mark.parametrize("chunks", [1, 4])
def test_decode_all_lengths_x_flags_x_chunking(length, optimized, chunks):
    """Every accepted word count decodes against every optimizer-flag and
    chunk-count combination of the source descriptor, keeping exactly the
    fields its layout can carry: 10 words strip the topology (and with it
    every schedule flag), 15 strip the flags word, 16 strip the chunk
    count, 17 carry everything."""
    desc = _planned_desc(optimized=optimized, chunks=chunks)
    words = desc.encode()
    assert words.shape == ((_CHUNK_WORDS if chunks > 1 else _OPT_WORDS),)
    if length > len(words):  # 17-word slice of an unchunked encoding
        pytest.skip("encoding has no chunk word to slice")
    back = CollectiveDescriptor.decode(words[:length])
    if length == _LEGACY_WORDS:
        assert back.axes == () and back.split == ()
        assert back.optimized is False and back.chunks == 1
        assert back.backend == ""
    else:
        assert back.axes == desc.axes and back.split == desc.split
        assert back.optimized is (optimized and length >= _OPT_WORDS)
        assert back.chunks == (chunks if length == _CHUNK_WORDS else 1)
    # the shared prefix is what the shorter layouts decoded — re-encoding
    # the truncated decode reproduces those bytes
    np.testing.assert_array_equal(back.encode()[:length], words[:length])


@pytest.mark.parametrize("backend", sorted(_WIRE_BACKENDS))
@pytest.mark.parametrize("optimized", [False, True])
@pytest.mark.parametrize("chunks", [1, 2])
def test_backend_round_trips_in_flags_word(backend, optimized, chunks):
    desc = _planned_desc(
        backend=backend, optimized=optimized, chunks=chunks
    )
    words = desc.encode()
    assert words[_OPT_WORDS - 1] == (
        int(optimized) | (_WIRE_BACKENDS.index(backend) << 1)
    )
    back = CollectiveDescriptor.decode(words)
    assert back == desc
    assert back.backend == backend
    # the default backend changes no bytes vs. the pre-registry encoding
    if backend == "":
        np.testing.assert_array_equal(
            words,
            _planned_desc(optimized=optimized, chunks=chunks).encode(),
        )


def test_backend_requires_topology():
    with pytest.raises(ValueError, match="multi-axis"):
        CollectiveDescriptor(comm_size=8, count=16, backend="pallas")


def test_unknown_backend_name_rejected():
    with pytest.raises(ValueError, match="not wire-encodable"):
        _planned_desc(backend="netfpga")


def test_unknown_backend_wire_id_rejected():
    words = _planned_desc().encode().copy()
    words[_OPT_WORDS - 1] = len(_WIRE_BACKENDS) << 1
    with pytest.raises(ValueError, match="unknown lowering-backend"):
        CollectiveDescriptor.decode(words)


def test_topology_words_internally_consistent_on_decode():
    """A topology word vector whose sizes don't factor comm_size is rejected
    by the descriptor invariant, not silently accepted."""
    desc = CollectiveDescriptor(
        comm_size=8, axes=(2, 4), count=4, coll_type=CollType.SCAN
    )
    words = desc.encode().copy()
    words[1] = 9  # comm_size no longer equals prod(axes)
    with pytest.raises(ValueError, match="factor"):
        CollectiveDescriptor.decode(words)
