"""Multi-device layer equivalence (subprocess: forced 8-device host platform).

These are the paper-technique correctness gates:
  * EP MoE (scan-offset dispatch + all_to_all) == dense dropless reference
  * sequence-parallel Mamba2 (dist_exscan state hand-off) == unsharded mixer
  * int8+error-feedback compressed DP == f32 DP convergence parity
"""


def test_moe_ep_equivalence(subprocess_runner):
    subprocess_runner("repro.testing.moe_check")


def test_mamba_sequence_parallel_equivalence(subprocess_runner):
    subprocess_runner("repro.testing.mamba_sp_check")


def test_compressed_dp_convergence(subprocess_runner):
    subprocess_runner("repro.testing.compressed_dp_check")
