"""Perf-flag variants must preserve model semantics (same loss/logits)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import perf_flags
from repro.configs import get_config
from repro.models import build_model


@pytest.fixture(autouse=True)
def _restore_flags():
    saved = dataclasses.asdict(perf_flags.FLAGS)
    yield
    perf_flags.set_flags(**saved)


def _loss(arch, **flags):
    perf_flags.set_flags(**flags)
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32),
    }
    loss, _ = api.loss(params, batch)
    return float(loss)


def test_attention_flags_same_loss():
    base = _loss("qwen25_14b")
    for flags in (
        dict(attn_probs_bf16=True),
        dict(attn_kv_block=2048),
        dict(seq_shard_attn=True),  # no mesh: falls back, must still work
    ):
        assert abs(_loss("qwen25_14b", **flags) - base) < 5e-2, flags


def test_scan_algorithm_flags_same_loss():
    base = _loss("mamba2_130m")
    for algo in ("hillis_steele", "sklansky", "sequential_pipelined"):
        got = _loss("mamba2_130m", scan_algorithm=algo)
        assert abs(got - base) < 1e-3, algo


def test_remat_policy_same_loss_and_grads():
    cfg = get_config("smollm_360m").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)), jnp.int32),
    }
    (l0, _), g0 = jax.value_and_grad(api.loss, has_aux=True)(params, batch)
    perf_flags.set_flags(remat_policy="save_block_outputs")
    (l1, _), g1 = jax.value_and_grad(api.loss, has_aux=True)(params, batch)
    assert abs(float(l0) - float(l1)) < 1e-5
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=1e-4, rtol=1e-3,
        )


def test_parse_opt_string():
    perf_flags.parse_opt_string(
        "seq_shard_attn=1,remat_policy=save_block_outputs,attn_kv_block=2048,"
        "scan_algorithm=sklansky,ssm_chunk=128"
    )
    f = perf_flags.FLAGS
    assert f.seq_shard_attn and f.remat_policy == "save_block_outputs"
    assert f.attn_kv_block == 2048 and f.scan_algorithm == "sklansky"
    assert f.ssm_chunk == 128
