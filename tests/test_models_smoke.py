"""Per-architecture smoke tests (assignment deliverable f).

Each assigned arch instantiates its REDUCED family-preserving config and runs
one forward/train step on CPU asserting output shapes + finite values, plus a
prefill+decode step. The FULL configs are exercised only via the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model


def _batch(cfg, B=2, S=32, train=True):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if train:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32
        )
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_frames, cfg.d_model)), jnp.float32
        )
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_patches, cfg.d_model)), jnp.float32
        )
        batch["positions3"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, 3)
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finiteness(arch):
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(api.loss, has_aux=True)(
        params, batch
    )
    assert jnp.isfinite(loss), (arch, loss)
    gnorm = sum(
        float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
        for g in jax.tree.leaves(grads)
    )
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode(arch):
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))
    B, S = 2, 32
    batch = _batch(cfg, B, S, train=False)
    last_logits, cache = api.prefill(params, batch)
    assert last_logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(last_logits, np.float32)).all(), arch

    full = api.init_cache(B, S + 8)

    def place(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        pads = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
        return jnp.pad(src.astype(dst.dtype), pads)

    cache2 = jax.tree.map(place, full, cache)
    tok = jnp.argmax(last_logits[:, -1:], -1).astype(jnp.int32)
    ntok, cache3 = api.decode_step(params, tok, cache2, jnp.array(S, jnp.int32))
    assert ntok.shape == (B, 1)
    assert (np.asarray(ntok) >= 0).all() and (
        np.asarray(ntok) < cfg.padded_vocab
    ).all()
    # cache structurally preserved
    jax.tree.map(lambda a, b: None if a.shape == b.shape else 1 / 0, cache2, cache3)


def test_gemma_local_global_pattern_differs():
    """Sliding-window flags must actually change the computation."""
    import dataclasses
    cfg = get_config("gemma3_27b").reduced()
    cfg_nw = dataclasses.replace(cfg, sliding_window=0, local_global_ratio=0)
    api = build_model(cfg)
    api_nw = build_model(cfg_nw)
    params = api.init(jax.random.key(0))
    batch = _batch(cfg, 1, 64, train=False)
    from repro.models.transformer import lm_forward
    la, _ = lm_forward(params, batch["tokens"], cfg)
    lb, _ = lm_forward(params, batch["tokens"], cfg_nw)
    assert not np.allclose(np.asarray(la, np.float32), np.asarray(lb, np.float32))


def test_decode_matches_forward_logits():
    """Greedy decode continuation equals the full-forward argmax path."""
    cfg = get_config("smollm_360m").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(1))
    rng = np.random.default_rng(2)
    B, S = 1, 16
    toks = rng.integers(2, cfg.vocab_size, (B, S)).astype(np.int32)

    # path A: prefill then one decode step
    last_logits, cache = api.prefill(params, {"tokens": jnp.asarray(toks)})
    t1 = int(jnp.argmax(last_logits[0, -1]))
    full = api.init_cache(B, S + 4)

    def place(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        pads = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
        return jnp.pad(src.astype(dst.dtype), pads)

    cache = jax.tree.map(place, full, cache)
    t2, _ = api.decode_step(
        params, jnp.asarray([[t1]], jnp.int32), cache, jnp.array(S, jnp.int32)
    )

    # path B: full forward over [toks, t1]
    from repro.models.transformer import lm_forward
    toks_b = np.concatenate([toks, [[t1]]], axis=1)
    logits, _ = lm_forward(params, jnp.asarray(toks_b), cfg)
    t2_ref = int(jnp.argmax(logits[0, -1]))
    assert int(t2[0, 0]) == t2_ref
