"""Closed-form validation of the trip-count-aware HLO cost parser."""

import jax
import jax.numpy as jnp
from jax import lax

from repro.roofline.hlo_cost import hlo_cost, parse_module


def _compile_text(fn, *shapes):
    return jax.jit(fn).lower(*shapes).compile().as_text()


def test_single_matmul_flops_and_bytes():
    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    txt = _compile_text(lambda a, b: a @ b, x, w)
    c = hlo_cost(txt, 1)
    assert abs(c.flops - 2 * 256 * 512 * 128) / c.flops < 0.01
    expect_bytes = (256 * 512 + 512 * 128 + 256 * 128) * 4
    assert 0.5 < c.bytes / expect_bytes < 2.5


def test_scan_trip_count_multiplies():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = lax.scan(body, x, None, length=7)
        return out

    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = _compile_text(f, s, s)
    c = hlo_cost(txt, 1)
    expect = 7 * (2 * 128**3)
    assert 0.95 < c.flops / expect < 1.15


def test_nested_scan():
    def g(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = lax.scan(outer, x, None, length=5)
        return out

    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    txt = _compile_text(g, s, s)
    c = hlo_cost(txt, 1)
    assert 0.95 < c.flops / (15 * 2 * 128**3) < 1.15


def test_gqa_einsum_flops():
    def f(q, k):
        return jnp.einsum("bqhgd,bkhd->bhgqk", q, k)

    q = jax.ShapeDtypeStruct((2, 64, 4, 2, 32), jnp.float32)
    k = jax.ShapeDtypeStruct((2, 128, 4, 32), jnp.float32)
    txt = _compile_text(f, q, k)
    c = hlo_cost(txt, 1)
    expect = 2 * (2 * 4 * 2 * 64 * 128) * 32
    assert 0.95 < c.flops / expect < 1.1


def test_parse_module_finds_entry():
    txt = _compile_text(lambda a: a + 1.0, jax.ShapeDtypeStruct((8,), jnp.float32))
    comps, entry = parse_module(txt)
    assert entry is not None and entry in comps
