"""Property tests for the scan-collective schedules (simulator backend).

The SimBackend has identical messaging semantics to the SPMD backend
(zero-fill on missing in-edges), so hypothesis can sweep rank counts and
operators cheaply on one device; the real-ppermute path is covered by
tests/test_dist_scan_spmd.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing.hypothesis_compat import given, settings, strategies as st

from repro.core import (
    ALGORITHMS,
    MAX,
    SSD,
    SUM,
    CollectiveDescriptor,
    algorithm_step_count,
    cost_table,
    estimate_cost,
    get_operator,
    host_scan,
    schedule_trace,
    select_algorithm,
    sim_scan,
)

ALGOS = sorted(ALGORITHMS)
GENERIC_ALGOS = [a for a in ALGOS if a != "invertible_doubling"]


@settings(max_examples=40, deadline=None)
@given(
    p=st.integers(1, 24),
    n=st.integers(1, 5),
    algo=st.sampled_from(ALGOS),
    inclusive=st.booleans(),
    data=st.data(),
)
def test_sum_matches_cumsum(p, n, algo, inclusive, data):
    vals = data.draw(
        st.lists(
            st.lists(st.floats(-8, 8, width=32), min_size=n, max_size=n),
            min_size=p,
            max_size=p,
        )
    )
    x = np.asarray(vals, np.float32)
    want = np.cumsum(x, axis=0)
    if not inclusive:
        want = np.concatenate([np.zeros((1, n), np.float32), want[:-1]])
    got = np.asarray(
        sim_scan(jnp.asarray(x), "sum", p, algorithm=algo, inclusive=inclusive)
    )
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(p=st.integers(1, 17), algo=st.sampled_from(GENERIC_ALGOS), data=st.data())
def test_max_scan(p, algo, data):
    vals = data.draw(
        st.lists(st.floats(-100, 100, width=32), min_size=p, max_size=p)
    )
    x = np.asarray(vals, np.float32)[:, None]
    want = np.maximum.accumulate(x, axis=0)
    got = np.asarray(sim_scan(jnp.asarray(x), "max", p, algorithm=algo))
    np.testing.assert_allclose(got, want, atol=0, rtol=0)


@settings(max_examples=20, deadline=None)
@given(p=st.integers(1, 12), algo=st.sampled_from(GENERIC_ALGOS), data=st.data())
def test_ssd_noncommutative_pytree(p, algo, data):
    a = np.asarray(
        data.draw(st.lists(st.floats(0.25, 1.0, width=32), min_size=p, max_size=p)),
        np.float32,
    )[:, None]
    b = np.asarray(
        data.draw(st.lists(st.floats(-2, 2, width=32), min_size=p, max_size=p)),
        np.float32,
    )[:, None]
    A = np.empty_like(a)
    B = np.empty_like(b)
    A[0], B[0] = a[0], b[0]
    for j in range(1, p):
        A[j] = a[j] * A[j - 1]
        B[j] = a[j] * B[j - 1] + b[j]
    ga, gb = sim_scan((jnp.asarray(a), jnp.asarray(b)), SSD, p, algorithm=algo)
    np.testing.assert_allclose(np.asarray(ga), A, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gb), B, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("algo", ALGOS)
def test_step_counts_match_trace(algo):
    """The latency model's step count == the actual schedule's permute count."""
    for p in (2, 4, 8, 16):
        trace = schedule_trace(algo, p)
        # steps with no wire activity don't appear in latency; count nonempty
        nonempty = sum(1 for perm in trace if perm)
        assert nonempty <= algorithm_step_count(algo, p) + 1, (algo, p)
        assert nonempty >= 1


def test_sequential_message_economy():
    """Paper II-B1: sequential sends exactly p-1 point-to-point messages."""
    trace = schedule_trace("sequential", 8)
    total_msgs = sum(len(perm) for perm in trace)
    assert total_msgs == 7


def test_sklansky_multicast_pattern():
    """Paper Fig.3: sklansky steps contain one-to-many (repeated sources)."""
    trace = schedule_trace("sklansky", 8)
    last = trace[-1]
    srcs = [s for s, _ in last]
    assert len(srcs) != len(set(srcs)), "expected multicast (repeated source)"


def test_host_scan_equals_sim():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    for algo in GENERIC_ALGOS:
        a = np.asarray(host_scan(x, "sum", 8, algorithm=algo))
        b = np.asarray(sim_scan(x, "sum", 8, algorithm=algo))
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_selector_prefers_log_algorithms_at_scale():
    assert select_algorithm(256, 1 << 20, SUM) != "sequential"
    assert select_algorithm(256, 64, SUM) != "sequential"
    # tiny axis, tiny payload: anything goes, but must be a known algorithm
    assert select_algorithm(4, 64, SUM) in ALGORITHMS


def test_selector_respects_applicability():
    # MAX has no inverse: invertible_doubling must never be selected
    for p in (4, 16, 64, 256):
        for size in (64, 1 << 16, 1 << 24):
            assert select_algorithm(p, size, MAX) != "invertible_doubling"


def test_cost_table_monotone_in_payload():
    small = cost_table(16, 1 << 10)
    big = cost_table(16, 1 << 24)
    for k in small:
        assert big[k] > small[k]


def test_descriptor_roundtrip_and_node_type():
    d = CollectiveDescriptor(
        comm_id=3, comm_size=16, rank=7, algo_type="binomial_tree", count=256
    )
    assert CollectiveDescriptor.decode(d.encode()) == d
    assert CollectiveDescriptor(comm_size=8, rank=7).node_type.name == "ROOT"
    assert CollectiveDescriptor(comm_size=8, rank=0).node_type.name == "LEAF"
    assert CollectiveDescriptor(comm_size=8, rank=3).node_type.name == "INTERNAL"
