"""End-to-end driver: train a Mamba2 LM with the paper's scan collective in
the loss path (sequence-parallel SSD state hand-off via dist_exscan).

Uses the full production stack — data pipeline, AdamW + ZeRO specs,
checkpointing, fault-tolerant trainer — on whatever devices exist (1 CPU
device here; the identical code runs on the 16x16 pod mesh).

    PYTHONPATH=src python examples/train_ssm_seq_parallel.py [--steps 200]
"""

import argparse
import tempfile

import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import DataConfig, batches
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import Trainer, TrainerConfig
from repro.sharding.specs import Topology


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config("mamba2_130m").reduced()
    api = build_model(cfg)
    shape = ShapeConfig("example", args.seq, args.batch, "train")
    data = batches(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=0,
    ))
    with tempfile.TemporaryDirectory() as ckpt_dir:
        tr = Trainer(
            api, Topology(mesh=None), shape, data,
            TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=50, async_ckpt=True),
            AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps),
        )
        params, opt = tr.init_state()
        params, opt, hist = tr.run(params, opt, num_steps=args.steps)
    first = np.mean([h["loss"] for h in hist[:10]])
    last = np.mean([h["loss"] for h in hist[-10:]])
    print(f"steps={len(hist)}  loss {first:.3f} -> {last:.3f}")
    print(f"mean step time: {np.mean([h['step_time_s'] for h in hist[5:]])*1e3:.1f}ms")
    assert last < first, "training should reduce loss"
    print("OK: sequence-parallel SSM trained end-to-end.")


if __name__ == "__main__":
    main()
