"""Quickstart: the offloaded scan collective in 60 seconds.

Runs every algorithm from the paper on a simulated 8-rank communicator,
checks them against cumsum, shows the host-driven vs offloaded latency gap
(the paper's core claim), and prints the selector's algo_type choices.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (
    ALGORITHMS,
    SUM,
    CollectiveDescriptor,
    cost_table,
    select_algorithm,
    sim_scan,
    time_host_scan,
    time_offloaded_scan,
)

P = 8
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(P, 256)).astype(np.float32))
want = np.cumsum(np.asarray(x), axis=0)

print(f"== MPI_Scan over {P} ranks, payload 1KB ==")
for algo in sorted(ALGORITHMS):
    got = np.asarray(sim_scan(x, "sum", P, algorithm=algo))
    ok = np.allclose(got, want, atol=1e-4)
    t_sw = time_host_scan(x, "sum", P, algorithm=algo, iters=10)
    t_nf = time_offloaded_scan(x, "sum", P, algorithm=algo, iters=10)
    print(
        f"  {algo:22s} correct={ok}  software={t_sw*1e6:8.1f}us  "
        f"offloaded={t_nf*1e6:7.1f}us  speedup={t_sw/t_nf:6.1f}x"
    )

print("\n== runtime algorithm selection (paper: 'intelligent selection') ==")
for p in (8, 64, 256):
    for msg in (64, 1 << 16, 1 << 22):
        algo = select_algorithm(p, msg, SUM)
        print(f"  p={p:4d} payload={msg:>8d}B -> {algo}")

print("\n== the offload descriptor (paper Fig. 1) ==")
d = CollectiveDescriptor(comm_size=P, rank=3, algo_type="binomial_tree", count=256)
print(f"  {d}")
print(f"  wire encoding: {d.encode().tolist()}")
print(f"  node_type (derived): {d.node_type.name}")
print(f"  cost table @1KB: "
      + ", ".join(f"{k}={v*1e6:.1f}us" for k, v in cost_table(P, 1024).items()))
