"""The scan primitive inside MoE routing: exclusive-scan dispatch offsets.

Shows the paper's primitive working at a second layer of the stack: expert
dispatch computes per-expert buffer offsets with an EXCLUSIVE prefix scan
(kernels.ops.prefix_scan — the Pallas path), and validates a full MoE block
against the dropless reference.

    PYTHONPATH=src python examples/moe_scan_routing.py
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels.ops import prefix_scan
from repro.models.moe import _dense_moe, init_moe

cfg = dataclasses.replace(
    get_config("olmoe_1b_7b").reduced(), moe_num_experts=8, moe_top_k=2
)
rng = np.random.default_rng(0)

# --- 1. routing offsets via exclusive scan ---------------------------------
counts = jnp.asarray(rng.integers(0, 40, size=8), jnp.int32)
starts = prefix_scan(counts[None, :].astype(jnp.int32), op="add",
                     exclusive=True, force_pallas=True)[0]
print("tokens per expert:  ", np.asarray(counts))
print("dispatch offsets:   ", np.asarray(starts))
assert np.array_equal(
    np.asarray(starts),
    np.concatenate([[0], np.cumsum(np.asarray(counts))[:-1]]),
)

# --- 2. the full MoE block -------------------------------------------------
p = init_moe(jax.random.key(0), cfg, jnp.float32)
x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)).astype(np.float32))
y, aux = _dense_moe(p, x, cfg, "silu")
print(f"moe out shape: {y.shape}, load_balance={float(aux['load_balance']):.3f}, "
      f"router_z={float(aux['router_z']):.3f}")
assert np.isfinite(np.asarray(y)).all()
print("OK: scan-offset routing + MoE block. (EP all_to_all path: "
      "python -m repro.testing.moe_check)")
