"""Serve a small model with batched requests (continuous batching engine).

Trains a tiny LM briefly so generations aren't pure noise, then serves a
burst of requests through the ServeEngine: prefill -> slot splice -> batched
greedy decode, exercising the same decode_step the dry-run compiles for the
decode_32k / long_500k cells.

Two serving engines run as *tenants* of one shared offload service
(`repro.service.DescriptorBroker`): each engine's per-step slot-stats
reduction is a wire-encoded ALLREDUCE request, and because both engines
post the same descriptor shape, the broker coalesces their dispatches —
the serving analogue of two host ranks sharing the paper's one NetFPGA.

    PYTHONPATH=src python examples/serve_decode.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, batches
from repro.models import build_model
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.service import DescriptorBroker
from repro.serving.engine import Request, ServeEngine
from repro.sharding.specs import Topology


def main() -> None:
    cfg = get_config("smollm_360m").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))

    # brief training so the model learns the synthetic bigram structure
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100)
    data = batches(DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, seed=0))

    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(api.loss, has_aux=True)(params, batch)
        p2, o2, _ = adamw_update(g, opt, params, ocfg)
        return p2, o2, loss

    for i in range(60):
        b = next(data)
        params, opt, loss = step(params, opt, {k: jnp.asarray(v) for k, v in b.items()})
    print(f"trained 60 steps, loss={float(loss):.3f}")

    # one shared offload service; each ServeEngine is a tenant
    broker = DescriptorBroker(flush_interval_s=0.02).start()
    engines = [
        ServeEngine(
            api, params, Topology(mesh=None), batch_size=4, max_len=96,
            collective_client=broker.client(f"serve{i}"),
        )
        for i in range(2)
    ]
    rng = np.random.default_rng(1)
    reqs = []
    for rid in range(12):
        start = int(rng.integers(2, cfg.vocab_size - 32))
        prompt = np.arange(start, start + 12, dtype=np.int32) % cfg.vocab_size
        r = Request(rid=rid, prompt=prompt, max_new_tokens=8)
        reqs.append(r)
        engines[rid % 2].submit(r)
    # interleave the two tenants' decode steps so their per-step service
    # requests land in the same coalescing window
    while any(
        e.queue or any(s is not None for s in e.slots) for e in engines
    ):
        for e in engines:
            e.step()

    hits = 0
    total = 0
    for r in reqs:
        expect = [(int(r.prompt[-1]) + 1 + i) for i in range(len(r.generated))]
        match = sum(1 for g, e in zip(r.generated, expect) if g == e)
        hits += match
        total += len(r.generated)
        print(f"req {r.rid}: prompt tail {r.prompt[-3:].tolist()} -> {r.generated}")
    print(f"next-token structure hit-rate: {hits}/{total}")

    for i, e in enumerate(engines):
        stats = e.collect_service_stats()
        print(f"engine{i} service stats: {stats}")
    broker.stop()
    snap = broker.telemetry.snapshot()
    print(
        f"service: coalesce_factor={snap['coalesce_factor']:.2f} "
        f"fused {snap['fused_requests']} requests into "
        f"{snap['fused_dispatches']} dispatches across "
        f"{len(snap['tenants'])} tenants"
    )
    print("OK: batched serving drained all requests through the service.")


if __name__ == "__main__":
    main()
