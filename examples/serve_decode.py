"""Serve a small model with batched requests (continuous batching engine).

Trains a tiny LM briefly so generations aren't pure noise, then serves a
burst of requests through the ServeEngine: prefill -> slot splice -> batched
greedy decode, exercising the same decode_step the dry-run compiles for the
decode_32k / long_500k cells.

    PYTHONPATH=src python examples/serve_decode.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, batches
from repro.models import build_model
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.serving.engine import Request, ServeEngine
from repro.sharding.specs import Topology


def main() -> None:
    cfg = get_config("smollm_360m").reduced()
    api = build_model(cfg)
    params = api.init(jax.random.key(0))

    # brief training so the model learns the synthetic bigram structure
    opt = init_opt_state(params)
    ocfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100)
    data = batches(DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, seed=0))

    @jax.jit
    def step(params, opt, batch):
        (loss, _), g = jax.value_and_grad(api.loss, has_aux=True)(params, batch)
        p2, o2, _ = adamw_update(g, opt, params, ocfg)
        return p2, o2, loss

    for i in range(60):
        b = next(data)
        params, opt, loss = step(params, opt, {k: jnp.asarray(v) for k, v in b.items()})
    print(f"trained 60 steps, loss={float(loss):.3f}")

    eng = ServeEngine(api, params, Topology(mesh=None), batch_size=4, max_len=96)
    rng = np.random.default_rng(1)
    reqs = []
    for rid in range(6):
        start = int(rng.integers(2, cfg.vocab_size - 32))
        prompt = np.arange(start, start + 12, dtype=np.int32) % cfg.vocab_size
        r = Request(rid=rid, prompt=prompt, max_new_tokens=8)
        reqs.append(r)
        eng.submit(r)
    eng.run_until_drained()

    hits = 0
    total = 0
    for r in reqs:
        expect = [(int(r.prompt[-1]) + 1 + i) for i in range(len(r.generated))]
        match = sum(1 for g, e in zip(r.generated, expect) if g == e)
        hits += match
        total += len(r.generated)
        print(f"req {r.rid}: prompt tail {r.prompt[-3:].tolist()} -> {r.generated}")
    print(f"next-token structure hit-rate: {hits}/{total}")
    print("OK: batched serving drained all requests.")


if __name__ == "__main__":
    main()
